//! **Executed** expert-parallel sharding — the measured counterpart of
//! [`crate::cluster::sim`]'s analytic EP model, now with a slot-level
//! double-buffered pipeline that overlaps comm and compute.
//!
//! [`ep_forward`] runs the MoE layer forward sharded across R simulated
//! ranks ([`crate::cluster::rank::RankGroup`]): experts are partitioned
//! `Partition::even(E, R)`, tokens `Partition::even(T, R)`, and each
//! top-k slot executes the real dispatch pipeline
//!
//! ```text
//! pack (per src rank: rows → per-destination send buffers)
//!   → in-memory all-to-all (u8 codes + UE8M0 sidecar as two buffers;
//!     dense rows as one — cluster/comm.rs's two-buffer model)
//!   → assemble (per dst rank: rows → [E_local·capacity, d] batch)
//!   → expert FFN (per rank, on its disjoint worker share)
//!   → combine (per-rank unpermute_unpad → reduce → gates)
//! ```
//!
//! **Chunked double buffering** ([`EpConfig::chunks`] = C): each rank's
//! expert range is split into C contiguous chunks, and the pipeline runs
//! per (rank, chunk) *unit*. With [`EpConfig::overlap`] the units are
//! scheduled on a [`crate::exec::steps::StepGraph`] — per rank one
//! **comm lane** (1 worker: pack, assemble, combine) and one **compute
//! lane** (the remaining workers: expert FFN) — so while rank r's
//! experts run chunk k, its comm lane packs and all-to-alls chunk k+1,
//! in both directions (the backward mirrors this). Lane budgets are
//! carved from the same process budget, so overlap never oversubscribes
//! (a 1-worker rank degrades to one merged lane = serial execution).
//! With `overlap = false` the same chunked units run bulk-synchronously,
//! which is the measured baseline for the overlap-efficiency report
//! ([`crate::cluster::sim::ep_overlap_report`]).
//!
//! **Bit-identity contract**: for any R, C, overlap flag and thread
//! budget, the output equals the single-rank
//! [`crate::moe::layer::moe_forward`] bit for bit
//! (`tests/prop_ep_shard.rs`). The pieces that make this hold:
//! per-expert math reads only that expert's `capacity` rows (so chunk
//! boundaries — always on expert boundaries, in plan order — change
//! nothing); the UE8M0 sidecar reproduces po2 scales exactly
//! (`scale == 2^sexp`); each token appears at most once per top-k slot,
//! so the combine reduce reads exactly one nonzero partial per served
//! token regardless of how units interleave in wall-clock; and every
//! kernel is thread-count-invariant (`tests/prop_parallel.rs`), so the
//! comm/compute lane split is bit-neutral.

use std::ops::Range;
use std::time::Instant;

use crate::cluster::fault::{wire_tick, FaultPlan};
use crate::cluster::rank::{all_to_all, RankGroup, WireBuf};
use crate::exec::{self, Handoff, Partition, StepGraph, StepId, WorkerGroup};
use crate::fp8::tensor::{n_tiles, Fp8Tensor, TileLayout};
use crate::fp8::tile::quantize_rowwise_with_threads;
use crate::fp8::{ue8m0, Fp8Format, ScaleMode};
use crate::moe::backward::{
    expert_ffn_bwd, mat_add_assign, router_backward_from_stash, scale_by_gates_with_threads,
    BwdStageTimes, BwdStats, ExpertBwd, FwdStash, MoeGrads, SlotStash,
};
use crate::moe::layer::{
    combine, expert_ffn, PreparedWeights, RankLocalBatch, Recipe, WirePayload,
};
use crate::moe::permute::permute_pad_plan;
use crate::moe::router::{route, Routing};
use crate::obs::{self, Counter};
use crate::train::native::{NativeTrainer, TrainMetrics};
use crate::util::json::Json;
use crate::util::mat::Mat;

/// Execution parameters for one EP-sharded forward/backward.
#[derive(Clone, Copy, Debug)]
pub struct EpConfig {
    /// Number of simulated ranks (expert shards).
    pub ranks: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Per-expert row budget.
    pub capacity: usize,
    /// Total worker budget shared by all ranks (0 = resolve via
    /// [`crate::exec::threads`]). Each rank gets a disjoint share.
    pub threads: usize,
    /// Pipeline chunks per rank (≥ 1; clamped to the rank's expert
    /// count). `1` reproduces the original single-shot pipeline.
    pub chunks: usize,
    /// Overlap comm and compute: run the chunked units on a
    /// [`crate::exec::steps::StepGraph`] with a dedicated comm lane per
    /// rank, so chunk k+1's pack/all-to-all/assemble hides behind chunk
    /// k's expert FFN. `false` = bulk-synchronous chunked schedule
    /// (bitwise identical output either way).
    pub overlap: bool,
}

impl EpConfig {
    /// Serialized single-chunk config — the PR-2 pipeline.
    pub fn serial(ranks: usize, top_k: usize, capacity: usize, threads: usize) -> EpConfig {
        EpConfig { ranks, top_k, capacity, threads, chunks: 1, overlap: false }
    }

    /// The same config with a chunked (and optionally overlapped)
    /// pipeline.
    pub fn with_pipeline(mut self, chunks: usize, overlap: bool) -> EpConfig {
        self.chunks = chunks;
        self.overlap = overlap;
        self
    }
}

/// Shape of one executed EP forward — shared by the runtime, the
/// simulator's model ([`crate::cluster::sim::modeled_ep_stages`]) and the
/// `epshard` CLI.
#[derive(Clone, Copy, Debug)]
pub struct EpShape {
    /// Token rows.
    pub tokens: usize,
    /// Model width.
    pub d_model: usize,
    /// Per-expert FFN hidden size.
    pub ffn: usize,
    /// Expert count.
    pub n_experts: usize,
    /// Routed experts per token.
    pub top_k: usize,
    /// Per-expert row budget.
    pub capacity: usize,
}

impl EpShape {
    /// Derive the shape from an input/weights/config triple.
    pub fn of(x: &Mat, w: &PreparedWeights, cfg: &EpConfig) -> EpShape {
        EpShape {
            tokens: x.rows,
            d_model: x.cols,
            ffn: w.raw.w1[0].cols,
            n_experts: w.raw.n_experts(),
            top_k: cfg.top_k,
            capacity: cfg.capacity,
        }
    }
}

/// Accumulated seconds per pipeline stage (summed over the top-k slots;
/// route and entry-quant run once). In the serialized schedule these are
/// disjoint wall-clock intervals; in the overlapped schedule they are
/// summed per-step **busy** times whose intervals overlap — compare
/// [`EpForward::pipeline_wall_s`] for the real elapsed time.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// Router seconds.
    pub route_s: f64,
    /// Entry-quantization seconds.
    pub quant_s: f64,
    /// Dispatch (permute + wire) seconds.
    pub dispatch_s: f64,
    /// Expert GEMM seconds.
    pub expert_s: f64,
    /// Combine (wire + unpermute) seconds.
    pub combine_s: f64,
}

impl StageTimes {
    /// Sum of all stages.
    pub fn total_s(&self) -> f64 {
        self.route_s + self.quant_s + self.dispatch_s + self.expert_s + self.combine_s
    }
}

/// Result of one executed EP-sharded forward: the output plus the
/// measurements the simulator can only model.
pub struct EpForward {
    /// Layer output `[t, d]`.
    pub y: Mat,
    /// Load-balancing aux loss.
    pub aux_loss: f32,
    /// Rank count the forward ran with.
    pub ranks: usize,
    /// Effective pipeline chunks per rank (the configured count clamped
    /// to the per-rank expert count).
    pub chunks: usize,
    /// Whether the overlapped (step-graph) schedule ran.
    pub overlap: bool,
    /// Per-stage seconds (busy-time semantics under overlap — see
    /// [`StageTimes`]).
    pub stages: StageTimes,
    /// Dispatch-stage **wall** seconds: the interval-union length of all
    /// pack/assemble step intervals, summed over slots. Equal to the
    /// busy time in the serialized schedule (disjoint intervals); under
    /// overlap strictly ≤ busy — reporting both is what makes the two
    /// schedules' stage records comparable without footnotes.
    pub dispatch_wall_s: f64,
    /// Expert-stage wall seconds (interval union of FFN steps).
    pub expert_wall_s: f64,
    /// Combine-stage wall seconds (interval union of combine steps plus
    /// the serving reduce, which is always driver-serial).
    pub combine_wall_s: f64,
    /// Wall-clock seconds of the dispatch→FFN→combine pipeline, summed
    /// over slots (excludes route and entry-quant, which run identically
    /// outside the pipeline in both schedules) — the serialized-vs-
    /// overlapped comparison the overlap-efficiency report is built on.
    pub pipeline_wall_s: f64,
    /// Per-slot pipeline wall-clock seconds (one entry per top-k slot).
    pub slot_wall_s: Vec<f64>,
    /// Per-rank expert-stage seconds (summed over slots) — the load
    /// imbalance the capacity model hides.
    pub rank_expert_s: Vec<f64>,
    /// Dispatch payload bytes actually shipped (real rows only — padding
    /// never crosses the wire).
    pub dispatch_payload_bytes: usize,
    /// UE8M0 scale sidecar bytes (FP8 wire only).
    pub dispatch_sidecar_bytes: usize,
    /// Number of separate wire buffers (the synchronization-count proxy:
    /// FP8 ships 2 per src→dst-unit pair, BF16 ships 1; chunking
    /// multiplies pairs, not bytes).
    pub dispatch_buffers: usize,
    /// Combine-path bytes (always BF16-accounted — §3.3 keeps the
    /// combine in BF16 for gradient safety).
    pub combine_bytes: usize,
}

impl EpForward {
    /// Per-stage report as JSON (for `runs/epshard_*.json`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ranks", self.ranks)
            .set("chunks", self.chunks)
            .set("overlap", self.overlap)
            .set("route_ms", self.stages.route_s * 1e3)
            .set("quant_ms", self.stages.quant_s * 1e3)
            .set("dispatch_ms", self.stages.dispatch_s * 1e3)
            .set("expert_ms", self.stages.expert_s * 1e3)
            .set("combine_ms", self.stages.combine_s * 1e3)
            .set("dispatch_wall_ms", self.dispatch_wall_s * 1e3)
            .set("expert_wall_ms", self.expert_wall_s * 1e3)
            .set("combine_wall_ms", self.combine_wall_s * 1e3)
            .set("total_ms", self.stages.total_s() * 1e3)
            .set("pipeline_wall_ms", self.pipeline_wall_s * 1e3)
            .set(
                "slot_wall_ms",
                self.slot_wall_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set(
                "rank_expert_ms",
                self.rank_expert_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set("dispatch_payload_bytes", self.dispatch_payload_bytes)
            .set("dispatch_sidecar_bytes", self.dispatch_sidecar_bytes)
            .set("dispatch_buffers", self.dispatch_buffers)
            .set("combine_bytes", self.combine_bytes)
            .set("aux_loss", self.aux_loss)
    }
}

// ---------------------------------------------------------------------
// chunk layout + lanes
// ---------------------------------------------------------------------

/// One (rank, chunk) pipeline unit: a contiguous sub-range of the
/// owning rank's experts, in plan order.
#[derive(Clone, Debug)]
struct Unit {
    rank: usize,
    chunk: usize,
    experts: Range<usize>,
}

/// The chunked unit layout: rank-major units covering experts `0..E` in
/// ascending order (chunk boundaries respect plan order, which is what
/// keeps the combine reduce order — and therefore the bits — fixed).
struct ChunkLayout {
    units: Vec<Unit>,
    /// Unit-index range per rank.
    rank_units: Vec<Range<usize>>,
    /// Max per-rank chunk count = pipeline round count.
    c_max: usize,
    /// Global expert id → unit id.
    unit_of_expert: Vec<usize>,
}

impl ChunkLayout {
    fn new(ex_part: &Partition, n_experts: usize, chunks: usize) -> ChunkLayout {
        assert!(chunks >= 1, "need at least one pipeline chunk");
        let mut units = Vec::new();
        let mut rank_units = Vec::new();
        let mut c_max = 0;
        for er in ex_part.ranges() {
            // `even` clamps to the expert count, so a 2-expert rank asked
            // for 4 chunks runs 2 — never an empty unit.
            let sub = Partition::even(er.len(), chunks);
            let start = units.len();
            for (c, sr) in sub.ranges().enumerate() {
                units.push(Unit {
                    rank: rank_units.len(),
                    chunk: c,
                    experts: er.start + sr.start..er.start + sr.end,
                });
            }
            c_max = c_max.max(sub.len());
            rank_units.push(start..units.len());
        }
        let mut unit_of_expert = vec![0usize; n_experts];
        for (u, unit) in units.iter().enumerate() {
            for ex in unit.experts.clone() {
                unit_of_expert[ex] = u;
            }
        }
        ChunkLayout { units, rank_units, c_max, unit_of_expert }
    }

    /// Unit id of `(rank, chunk)`, or `None` when the rank has fewer
    /// chunks than the pipeline's round count.
    fn unit_id(&self, rank: usize, chunk: usize) -> Option<usize> {
        let ru = self.rank_units[rank].clone();
        (chunk < ru.len()).then_some(ru.start + chunk)
    }
}

/// Per-destination expert ranges for pipeline round `c` (empty range for
/// ranks with fewer chunks — they get an empty, but present, wire
/// buffer, keeping the mailbox square).
fn chunk_dsts(layout: &ChunkLayout, c: usize, n_ranks: usize) -> Vec<Range<usize>> {
    (0..n_ranks)
        .map(|rk| layout.unit_id(rk, c).map_or(0..0, |u| layout.units[u].experts.clone()))
        .collect()
}

/// Step-graph lane assignment for the overlapped schedule: per rank one
/// comm lane (1 worker) and one compute lane (the rest), merged into a
/// single serial lane when the rank's share is a single worker. Lane
/// budgets sum to the rank's [`WorkerGroup`] share, so the overlapped
/// schedule uses exactly the worker budget the serialized one does.
struct Lanes {
    n_lanes: usize,
    /// Comm lane index per rank (pack / assemble / combine steps).
    comm: Vec<usize>,
    /// Compute lane index per rank (expert FFN steps).
    compute: Vec<usize>,
    /// Worker budget for compute-lane kernels, per rank.
    compute_budget: Vec<usize>,
}

impl Lanes {
    fn new(n_ranks: usize, total_workers: usize) -> Lanes {
        let g = WorkerGroup::new(n_ranks, total_workers);
        let (mut comm, mut compute, mut compute_budget) = (Vec::new(), Vec::new(), Vec::new());
        let mut n_lanes = 0;
        for rk in 0..n_ranks {
            let w = g.budget(rk);
            comm.push(n_lanes);
            if w >= 2 {
                compute.push(n_lanes + 1);
                compute_budget.push(w - 1);
                n_lanes += 2;
            } else {
                compute.push(n_lanes);
                compute_budget.push(1);
                n_lanes += 1;
            }
        }
        Lanes { n_lanes, comm, compute, compute_budget }
    }
}

/// Step classification for rolling [`crate::exec::steps::StepTime`]s up
/// into [`StageTimes`] (and the backward's [`BwdStageTimes`]).
#[derive(Clone, Copy)]
enum StepKind {
    Pack,
    Assemble,
    Ffn,
    Combine,
}

impl StepKind {
    /// Wall-accounting group: pack+assemble share the dispatch interval
    /// union, FFN and combine get their own.
    fn wall_group(self) -> usize {
        match self {
            StepKind::Pack | StepKind::Assemble => 0,
            StepKind::Ffn => 1,
            StepKind::Combine => 2,
        }
    }
}

/// Length of the union of (start, end) intervals, in the intervals'
/// time unit — the per-stage **wall** under overlap, where summed busy
/// times double-count concurrent lanes.
fn union_s(iv: &mut [(f64, f64)]) -> f64 {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (mut total, mut cur) = (0.0f64, None::<(f64, f64)>);
    for &(s, e) in iv.iter() {
        cur = match cur {
            Some((cs, ce)) if s <= ce => Some((cs, ce.max(e))),
            Some((cs, ce)) => {
                total += ce - cs;
                Some((s, e))
            }
            None => Some((s, e)),
        };
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Per-StepKind wall seconds (interval unions) from an executed step
/// graph's times.
fn step_walls(times: &[crate::exec::StepTime], kinds: &[(StepKind, usize)]) -> [f64; 3] {
    let mut iv: [Vec<(f64, f64)>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for st in times {
        iv[kinds[st.id].0.wall_group()].push((st.start_s, st.end_s));
    }
    [union_s(&mut iv[0]), union_s(&mut iv[1]), union_s(&mut iv[2])]
}

// ---------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------

/// Everything one top-k slot's forward pipeline reads (shared by the
/// serialized and overlapped drivers — their unit bodies are the same
/// code, which is half of the bit-identity argument).
struct FwdCtx<'a> {
    x: &'a Mat,
    x_q: Option<&'a Fp8Tensor>,
    w: &'a PreparedWeights,
    plan: &'a [i64],
    layout: &'a ChunkLayout,
    tok_part: &'a Partition,
    token_owner: &'a [usize],
    cap: usize,
    t: usize,
    d: usize,
    /// Top-k slot index (span `step` coordinate).
    kk: usize,
    /// Fault schedule the wire deliveries run under (unarmed = no-op).
    faults: &'a FaultPlan,
}

/// One slot's pipeline output: per-unit combine partials plus timings.
struct FwdSlotOut {
    partials: Vec<Mat>,
    dispatch_s: f64,
    expert_s: f64,
    combine_s: f64,
    /// Per-stage wall seconds `[dispatch, expert, combine]` (interval
    /// unions; == the busy times in the serialized schedule).
    walls: [f64; 3],
    rank_expert_s: Vec<f64>,
    wall_s: f64,
}

/// Bulk-synchronous chunked schedule: per round, all ranks pack →
/// all-to-all → assemble → FFN → combine, with a barrier between
/// stages. C = 1 is exactly the PR-2 pipeline.
fn fwd_slot_serial(cx: &FwdCtx, group: &RankGroup) -> FwdSlotOut {
    let r = group.n_ranks();
    let layout = cx.layout;
    let fmt = cx.x_q.map(|q| q.fmt);
    let mut partials: Vec<Option<Mat>> = (0..layout.units.len()).map(|_| None).collect();
    let (mut dispatch_s, mut expert_s, mut combine_s) = (0.0, 0.0, 0.0);
    let mut rank_expert_s = vec![0.0f64; r];
    let tw = Instant::now();
    for c in 0..layout.c_max {
        let dsts = chunk_dsts(layout, c, r);

        // ---- dispatch: pack → all-to-all → assemble ----
        let td = Instant::now();
        let mailbox = group
            .run_phase(|ctx| {
                let _sp = obs::enabled().then(|| {
                    obs::span(
                        format!("pack r{} c{c}", ctx.rank),
                        obs::SpanMeta::stage("pack").rank(ctx.rank).step(cx.kk).chunk(c),
                    )
                });
                let tr = part_range(cx.tok_part, ctx.rank);
                match cx.x_q {
                    Some(xq) => pack_fp8(xq, cx.plan, &tr, &dsts, cx.cap),
                    None => pack_dense(cx.x, cx.plan, &tr, &dsts, cx.cap),
                }
            })
            .results;
        let sa = obs::enabled().then(|| {
            obs::span(format!("a2a c{c}"), obs::SpanMeta::stage("a2a").step(cx.kk).chunk(c))
        });
        let inbox = all_to_all(mailbox);
        drop(sa);
        let batches = group
            .run_phase(|ctx| {
                layout.unit_id(ctx.rank, c).map(|u| {
                    let _ss = obs::enabled().then(|| {
                        obs::span(
                            format!("assemble r{} c{c}", ctx.rank),
                            obs::SpanMeta::stage("assemble")
                                .rank(ctx.rank)
                                .step(cx.kk)
                                .chunk(c),
                        )
                    });
                    // receiver-side integrity: checksum-verify each
                    // src→dst message, recovering injected corruption
                    // before assembly (no-op on an unarmed plan)
                    for (src, b) in inbox[ctx.rank].iter().enumerate() {
                        cx.faults.deliver(wire_tick(cx.kk, c, false), src, ctx.rank, b);
                    }
                    let er = layout.units[u].experts.clone();
                    match fmt {
                        Some(f) => assemble_fp8(
                            &inbox[ctx.rank],
                            cx.plan,
                            er,
                            cx.cap,
                            cx.d,
                            cx.token_owner,
                            f,
                        ),
                        None => assemble_dense(
                            &inbox[ctx.rank],
                            cx.plan,
                            er,
                            cx.cap,
                            cx.d,
                            cx.token_owner,
                        ),
                    }
                })
            })
            .results;
        dispatch_s += td.elapsed().as_secs_f64();

        // ---- expert FFN: each rank on its disjoint worker share ----
        let te = Instant::now();
        let ph = group.run_phase(|ctx| {
            batches[ctx.rank].as_ref().map(|b| {
                let _sf = obs::enabled().then(|| {
                    obs::span(
                        format!("ffn r{} c{c}", ctx.rank),
                        obs::SpanMeta::stage("ffn").rank(ctx.rank).step(cx.kk).chunk(c),
                    )
                });
                expert_ffn(b, cx.w, ctx.workers)
            })
        });
        for (i, s) in ph.rank_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }
        let yks = ph.results;
        expert_s += te.elapsed().as_secs_f64();

        // ---- combine: per-rank unpermute into token-indexed partials ----
        let tc = Instant::now();
        let parts = group
            .run_phase(|ctx| {
                layout.unit_id(ctx.rank, c).map(|u| {
                    let _sc = obs::enabled().then(|| {
                        obs::span(
                            format!("combine r{} c{c}", ctx.rank),
                            obs::SpanMeta::stage("combine")
                                .rank(ctx.rank)
                                .step(cx.kk)
                                .chunk(c),
                        )
                    });
                    let er = layout.units[u].experts.clone();
                    let yk = yks[ctx.rank].as_ref().expect("unit produced a batch");
                    combine(yk, cx.plan, er, cx.cap, cx.t, ctx.workers)
                })
            })
            .results;
        combine_s += tc.elapsed().as_secs_f64();
        for (rk, p) in parts.into_iter().enumerate() {
            if let Some(p) = p {
                partials[layout.unit_id(rk, c).expect("partial implies unit")] = Some(p);
            }
        }
    }
    let wall_s = tw.elapsed().as_secs_f64();
    FwdSlotOut {
        partials: partials.into_iter().map(|p| p.expect("every unit yields a partial")).collect(),
        dispatch_s,
        expert_s,
        combine_s,
        // Bulk-synchronous phases are disjoint wall intervals: wall == busy.
        walls: [dispatch_s, expert_s, combine_s],
        rank_expert_s,
        wall_s,
    }
}

/// Overlapped schedule: the same unit bodies on a [`StepGraph`]. Per
/// round the insertion order is `pack(·,c)`, `assemble(·,c)`,
/// `ffn(·,c)`, **then** `combine(·,c-1)` — so each comm lane packs and
/// assembles chunk c while its compute lane still runs chunk c-1's FFN,
/// and the combine of c-1 rides the comm lane once that FFN lands. The
/// all-to-all barrier is the dependency set (every assemble waits on all
/// packs of its round); the wire itself is a [`Handoff`] per
/// (src rank, dst unit).
fn fwd_slot_overlap(cx: &FwdCtx, lanes: &Lanes) -> FwdSlotOut {
    let r = lanes.comm.len();
    let layout = cx.layout;
    let n_units = layout.units.len();
    let wire: Vec<Handoff<WireBuf>> = (0..r * n_units).map(|_| Handoff::new()).collect();
    let batch_h: Vec<Handoff<RankLocalBatch>> = (0..n_units).map(|_| Handoff::new()).collect();
    let yk_h: Vec<Handoff<Mat>> = (0..n_units).map(|_| Handoff::new()).collect();
    let part_h: Vec<Handoff<Mat>> = (0..n_units).map(|_| Handoff::new()).collect();

    let mut g = StepGraph::new(lanes.n_lanes);
    let mut kinds: Vec<(StepKind, usize)> = Vec::new();
    let mut asm_id: Vec<Option<StepId>> = vec![None; n_units];
    let mut ffn_id: Vec<Option<StepId>> = vec![None; n_units];

    // Insertion order per round c: pack(·,c), assemble(·,c), ffn(·,c),
    // then combine(·,c-1) — so each comm lane packs and assembles chunk c
    // while its compute lane still runs chunk c-1's FFN, and the combine
    // of c-1 rides the comm lane once that FFN lands (the double buffer).
    // The round `c == c_max` exists only to flush the last combines.
    for c in 0..=layout.c_max {
        if c < layout.c_max {
            let dsts_c = chunk_dsts(layout, c, r);
            let unit_ids: Vec<Option<usize>> = (0..r).map(|rk| layout.unit_id(rk, c)).collect();
            // pack(·,c): one per src rank, no graph deps (pure function
            // of the inputs; same-lane insertion order serializes it
            // after the lane's earlier rounds)
            let packs: Vec<StepId> = (0..r)
                .map(|src| {
                    let (dsts, units) = (dsts_c.clone(), unit_ids.clone());
                    let tr = part_range(cx.tok_part, src);
                    let wire = &wire;
                    let id = g.add_with_meta(
                        lanes.comm[src],
                        &[],
                        format!("pack r{src} c{c}"),
                        obs::SpanMeta::stage("pack").rank(src).step(cx.kk).chunk(c),
                        move || {
                            let bufs = match cx.x_q {
                                Some(xq) => pack_fp8(xq, cx.plan, &tr, &dsts, cx.cap),
                                None => pack_dense(cx.x, cx.plan, &tr, &dsts, cx.cap),
                            };
                            for (dst, buf) in bufs.into_iter().enumerate() {
                                if let Some(u) = units[dst] {
                                    wire[src * n_units + u].put(buf);
                                }
                            }
                        },
                    );
                    kinds.push((StepKind::Pack, src));
                    id
                })
                .collect();
            // assemble(·,c): waits on every pack of round c (the a2a
            // barrier)
            for rk in 0..r {
                if let Some(u) = unit_ids[rk] {
                    let er = layout.units[u].experts.clone();
                    let (wire, batch_h) = (&wire, &batch_h);
                    let label = format!("assemble r{rk} c{c}");
                    let meta = obs::SpanMeta::stage("assemble").rank(rk).step(cx.kk).chunk(c);
                    let id = g.add_with_meta(lanes.comm[rk], &packs, label, meta, move || {
                        let inbox: Vec<WireBuf> =
                            (0..r).map(|src| wire[src * n_units + u].take()).collect();
                        // receiver-side integrity, same tick coordinate
                        // as the serialized schedule
                        for (src, b) in inbox.iter().enumerate() {
                            cx.faults.deliver(wire_tick(cx.kk, c, false), src, rk, b);
                        }
                        let b = match cx.x_q {
                            Some(xq) => assemble_fp8(
                                &inbox,
                                cx.plan,
                                er,
                                cx.cap,
                                cx.d,
                                cx.token_owner,
                                xq.fmt,
                            ),
                            None => {
                                assemble_dense(&inbox, cx.plan, er, cx.cap, cx.d, cx.token_owner)
                            }
                        };
                        batch_h[u].put(b);
                    });
                    kinds.push((StepKind::Assemble, rk));
                    asm_id[u] = Some(id);
                }
            }
            // ffn(·,c): compute lane, on the rank's remaining workers
            for rk in 0..r {
                if let Some(u) = unit_ids[rk] {
                    let (batch_h, yk_h) = (&batch_h, &yk_h);
                    let threads = lanes.compute_budget[rk];
                    let dep = asm_id[u].expect("ffn follows its unit's assemble");
                    let id = g.add_with_meta(
                        lanes.compute[rk],
                        &[dep],
                        format!("ffn r{rk} c{c}"),
                        obs::SpanMeta::stage("ffn").rank(rk).step(cx.kk).chunk(c),
                        move || {
                            let b = batch_h[u].take();
                            yk_h[u].put(expert_ffn(&b, cx.w, threads));
                        },
                    );
                    kinds.push((StepKind::Ffn, rk));
                    ffn_id[u] = Some(id);
                }
            }
        }
        // combine(·,c-1), on the comm lane
        if c >= 1 {
            let cc = c - 1;
            for rk in 0..r {
                if let Some(u) = layout.unit_id(rk, cc) {
                    let er = layout.units[u].experts.clone();
                    let (yk_h, part_h) = (&yk_h, &part_h);
                    let dep = ffn_id[u].expect("combine follows its unit's ffn");
                    g.add_with_meta(
                        lanes.comm[rk],
                        &[dep],
                        format!("combine r{rk} c{cc}"),
                        obs::SpanMeta::stage("combine").rank(rk).step(cx.kk).chunk(cc),
                        move || {
                            let yk = yk_h[u].take();
                            part_h[u].put(combine(&yk, cx.plan, er, cx.cap, cx.t, 1));
                        },
                    );
                    kinds.push((StepKind::Combine, rk));
                }
            }
        }
    }
    debug_assert_eq!(kinds.len(), g.n_steps());

    let times = g.run();
    let (mut dispatch_s, mut expert_s, mut combine_s) = (0.0, 0.0, 0.0);
    let mut rank_expert_s = vec![0.0f64; r];
    let mut wall_s = 0.0f64;
    for st in &times {
        let (kind, rk) = kinds[st.id];
        match kind {
            StepKind::Pack | StepKind::Assemble => dispatch_s += st.dur_s(),
            StepKind::Ffn => {
                expert_s += st.dur_s();
                rank_expert_s[rk] += st.dur_s();
            }
            StepKind::Combine => combine_s += st.dur_s(),
        }
        wall_s = wall_s.max(st.end_s);
    }
    let walls = step_walls(&times, &kinds);
    FwdSlotOut {
        partials: part_h.iter().map(|h| h.take()).collect(),
        dispatch_s,
        expert_s,
        combine_s,
        walls,
        rank_expert_s,
        wall_s,
    }
}

/// Run the MoE forward sharded across `cfg.ranks` simulated ranks.
/// Bit-identical to `moe_forward(x, w, cfg.top_k, cfg.capacity)` for any
/// rank count, chunk count and overlap flag.
pub fn ep_forward(x: &Mat, w: &PreparedWeights, cfg: &EpConfig) -> EpForward {
    ep_forward_with_faults(x, w, cfg, &FaultPlan::none())
}

/// [`ep_forward`] under a seeded [`FaultPlan`]: every all-to-all message
/// is checksum-verified on receive and injected faults are recovered
/// through bounded retransmission (`cluster/fault.rs`), so the output is
/// **still bit-identical** to the fault-free single-rank forward — only
/// the recovery counters and the virtual clock observe the faults.
pub fn ep_forward_with_faults(
    x: &Mat,
    w: &PreparedWeights,
    cfg: &EpConfig,
    faults: &FaultPlan,
) -> EpForward {
    let t = x.rows;
    let d = x.cols;
    let e = w.raw.n_experts();
    let r = cfg.ranks;
    assert!(r >= 1, "need at least one rank");
    assert!(e >= r, "cannot shard {e} experts across {r} ranks");
    assert!(t >= 1 && cfg.capacity >= 1);
    assert!(cfg.chunks >= 1, "need at least one pipeline chunk");
    let total_workers = if cfg.threads == 0 { exec::threads() } else { cfg.threads };
    let ex_part = Partition::even(e, r);
    let tok_part = Partition::even(t, r);
    let token_owner = owner_map(&tok_part, t);
    let layout = ChunkLayout::new(&ex_part, e, cfg.chunks);
    let group = (!cfg.overlap).then(|| RankGroup::new(r, total_workers));
    let lanes = cfg.overlap.then(|| Lanes::new(r, total_workers));

    let mut stages = StageTimes::default();

    let ts = Instant::now();
    let sr = obs::enabled().then(|| obs::span("route", obs::SpanMeta::stage("route")));
    let routing = route(x, &w.raw.router, cfg.top_k);
    drop(sr);
    stages.route_s = ts.elapsed().as_secs_f64();

    // Entry quantization (Fp8Flow's single cast). Row-independent, so
    // quantizing per token-owner rank would be bit-identical; run it
    // once over the batch with the full worker budget. Runs outside the
    // chunk pipeline in both schedules — one cast per batch, whatever C
    // is (the lint cross-check pins this chunk-invariance).
    let x_q = if w.recipe == Recipe::Fp8Flow {
        let tq = Instant::now();
        let sq = obs::enabled().then(|| obs::span("entry quant", obs::SpanMeta::stage("quant")));
        let q = quantize_rowwise_with_threads(x, Fp8Format::E4M3, ScaleMode::Po2, total_workers);
        drop(sq);
        obs::count(Counter::CastsFwd, 1); // Fp8Flow's single forward cast
        stages.quant_s = tq.elapsed().as_secs_f64();
        Some(q)
    } else {
        None
    };

    let mut y = Mat::zeros(t, d);
    let mut rank_expert_s = vec![0.0f64; r];
    let mut pipeline_wall_s = 0.0f64;
    let mut walls = [0.0f64; 3];
    let mut slot_wall_s = Vec::with_capacity(cfg.top_k);
    let (mut payload_b, mut sidecar_b) = (0usize, 0usize);
    let (mut n_bufs, mut combine_b) = (0usize, 0usize);

    for kk in 0..cfg.top_k {
        let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
        let plan = permute_pad_plan(&expert_of, e, cfg.capacity);
        // Each token appears at most once per slot: its serving unit.
        let serving = serving_map(&plan, &layout.unit_of_expert, cfg.capacity, t);

        // Wire accounting is analytic (sent_rows per src→dst-unit pair)
        // and runs outside the timers: bookkeeping must not contaminate
        // the measured stages, and the overlapped schedule consumes its
        // buffers inside the graph where they can't be inspected.
        let (p_b, s_b, b_b) = wire_accounting(
            &plan,
            &tok_part,
            &layout,
            cfg.capacity,
            r,
            d,
            x_q.as_ref().map(|_| n_tiles(d)),
        );
        payload_b += p_b;
        sidecar_b += s_b;
        n_bufs += b_b;
        combine_b += plan.iter().filter(|&&s| s >= 0).count() * d * 2;

        let cx = FwdCtx {
            x,
            x_q: x_q.as_ref(),
            w,
            plan: &plan,
            layout: &layout,
            tok_part: &tok_part,
            token_owner: &token_owner,
            cap: cfg.capacity,
            t,
            d,
            kk,
            faults,
        };
        let out = match (&group, &lanes) {
            (Some(g), _) => fwd_slot_serial(&cx, g),
            (_, Some(l)) => fwd_slot_overlap(&cx, l),
            _ => unreachable!("exactly one schedule is constructed"),
        };
        stages.dispatch_s += out.dispatch_s;
        stages.expert_s += out.expert_s;
        stages.combine_s += out.combine_s;
        for (w, s) in walls.iter_mut().zip(out.walls) {
            *w += s;
        }
        for (i, s) in out.rank_expert_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }

        // Reduce + gate, one task per token shard (disjoint y rows).
        // A token has at most one serving unit per slot, every other
        // partial holds exactly +0.0 there, and partial values are never
        // -0.0 (unpermute adds into zeros), so reading the serving
        // partial directly equals the full ascending-unit sum — and the
        // single-rank scatter — bit for bit. Dropped tokens contribute
        // g·(+0.0), which never changes y's bits (y is never -0.0).
        let tr_ = Instant::now();
        let sv = obs::enabled().then(|| {
            obs::span(format!("reduce k{kk}"), obs::SpanMeta::stage("combine").step(kk))
        });
        reduce_serving(&mut y, &out.partials, &serving, &tok_part, d, Some((&routing, kk)));
        drop(sv);
        let red = tr_.elapsed().as_secs_f64();
        stages.combine_s += red;
        walls[2] += red;
        let wall = out.wall_s + red;
        pipeline_wall_s += wall;
        slot_wall_s.push(wall);
    }

    EpForward {
        y,
        aux_loss: routing.aux_loss,
        ranks: r,
        chunks: layout.c_max,
        overlap: cfg.overlap,
        stages,
        dispatch_wall_s: walls[0],
        expert_wall_s: walls[1],
        combine_wall_s: walls[2],
        pipeline_wall_s,
        slot_wall_s,
        rank_expert_s,
        dispatch_payload_bytes: payload_b,
        dispatch_sidecar_bytes: sidecar_b,
        dispatch_buffers: n_bufs,
        combine_bytes: combine_b,
    }
}

// ---------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------

/// Result of one executed EP-sharded backward: the gradients plus the
/// wire measurements (the reverse-direction all-to-all).
pub struct EpBackward {
    /// The full layer gradients.
    pub grads: MoeGrads,
    /// Rank count the backward ran with.
    pub ranks: usize,
    /// Effective pipeline chunks per rank.
    pub chunks: usize,
    /// Whether the overlapped (step-graph) schedule ran.
    pub overlap: bool,
    /// Combine-bwd **wall** seconds: interval union of pack/assemble
    /// step intervals plus the driver-serial gate-scale/Q(dy) preamble
    /// (== busy in the serialized schedule; ≤ busy under overlap — the
    /// same busy/wall pairing as the forward's stage records).
    pub combine_bwd_wall_s: f64,
    /// Expert-backward wall seconds (interval union).
    pub expert_bwd_wall_s: f64,
    /// Dispatch-bwd wall seconds (interval union of the unpermute steps
    /// plus the driver-serial serving reduce).
    pub dispatch_bwd_wall_s: f64,
    /// Wall-clock seconds of the combine-bwd→expert-bwd→dispatch-bwd
    /// pipeline, summed over slots (excludes the gate-scale and Q(dy)
    /// preamble, which runs identically outside the pipeline in both
    /// schedules).
    pub pipeline_wall_s: f64,
    /// Per-slot pipeline wall-clock seconds.
    pub slot_wall_s: Vec<f64>,
    /// Per-rank expert-backward seconds (summed over slots).
    pub rank_expert_s: Vec<f64>,
    /// Combine-bwd payload bytes shipped (gate-scaled dy rows; FP8 codes
    /// on the Fp8Flow wire, BF16-accounted rows otherwise).
    pub dy_payload_bytes: usize,
    /// UE8M0 scale sidecar bytes on the combine-bwd wire (FP8 only).
    pub dy_sidecar_bytes: usize,
    /// Separate combine-bwd wire buffers (FP8 ships 2 per src→dst-unit
    /// pair).
    pub dy_buffers: usize,
    /// Dispatch-bwd bytes (dX rows back to token owners — accumulator
    /// precision, BF16-accounted, like the forward combine).
    pub dx_bytes: usize,
}

impl EpBackward {
    /// Per-stage report as JSON (for `runs/bwd_*.json`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ranks", self.ranks)
            .set("chunks", self.chunks)
            .set("overlap", self.overlap)
            .set("combine_bwd_ms", self.grads.stages.combine_bwd_s * 1e3)
            .set("expert_bwd_ms", self.grads.stages.expert_bwd_s * 1e3)
            .set("dispatch_bwd_ms", self.grads.stages.dispatch_bwd_s * 1e3)
            .set("combine_bwd_wall_ms", self.combine_bwd_wall_s * 1e3)
            .set("expert_bwd_wall_ms", self.expert_bwd_wall_s * 1e3)
            .set("dispatch_bwd_wall_ms", self.dispatch_bwd_wall_s * 1e3)
            .set("total_ms", self.grads.stages.total_s() * 1e3)
            .set("pipeline_wall_ms", self.pipeline_wall_s * 1e3)
            .set(
                "slot_wall_ms",
                self.slot_wall_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set(
                "rank_expert_ms",
                self.rank_expert_s.iter().map(|s| s * 1e3).collect::<Vec<f64>>(),
            )
            .set("casts", self.grads.stats.casts)
            .set("requants", self.grads.stats.requants)
            .set("dy_payload_bytes", self.dy_payload_bytes)
            .set("dy_sidecar_bytes", self.dy_sidecar_bytes)
            .set("dy_buffers", self.dy_buffers)
            .set("dx_bytes", self.dx_bytes)
    }
}

/// Everything one slot's backward pipeline reads.
struct BwdCtx<'a> {
    dyg: &'a Mat,
    dy_q: Option<&'a Fp8Tensor>,
    w: &'a PreparedWeights,
    slot: &'a SlotStash,
    plan: &'a [i64],
    layout: &'a ChunkLayout,
    tok_part: &'a Partition,
    token_owner: &'a [usize],
    cap: usize,
    t: usize,
    d: usize,
    /// Top-k slot index (span `step` coordinate).
    kk: usize,
    /// Fault schedule the wire deliveries run under (unarmed = no-op).
    faults: &'a FaultPlan,
}

/// One slot's backward pipeline output: per-unit dX partials, the
/// per-unit expert backward results (weight grads + cast stats, in
/// ascending unit = ascending expert order), and timings.
struct BwdSlotOut {
    partials: Vec<Mat>,
    ebs: Vec<ExpertBwd>,
    combine_bwd_s: f64,
    expert_bwd_s: f64,
    dispatch_bwd_s: f64,
    /// Per-stage wall seconds `[combine-bwd, expert-bwd, dispatch-bwd]`.
    walls: [f64; 3],
    rank_expert_s: Vec<f64>,
    wall_s: f64,
}

/// Bulk-synchronous chunked backward schedule (the forward's mirror).
fn bwd_slot_serial(cx: &BwdCtx, group: &RankGroup) -> BwdSlotOut {
    let r = group.n_ranks();
    let layout = cx.layout;
    let mut partials: Vec<Option<Mat>> = (0..layout.units.len()).map(|_| None).collect();
    let mut ebs: Vec<Option<ExpertBwd>> = (0..layout.units.len()).map(|_| None).collect();
    let (mut combine_bwd_s, mut expert_bwd_s, mut dispatch_bwd_s) = (0.0, 0.0, 0.0);
    let mut rank_expert_s = vec![0.0f64; r];
    let tw = Instant::now();
    for c in 0..layout.c_max {
        let dsts = chunk_dsts(layout, c, r);

        // ---- combine-bwd: pack → a2a → assemble (dy rows to experts) ----
        let tc = Instant::now();
        let mailbox = group
            .run_phase(|ctx| {
                let _sp = obs::enabled().then(|| {
                    obs::span(
                        format!("pack r{} c{c}", ctx.rank),
                        obs::SpanMeta::stage("pack").rank(ctx.rank).step(cx.kk).chunk(c),
                    )
                });
                let tr = part_range(cx.tok_part, ctx.rank);
                match cx.dy_q {
                    Some(q) => pack_fp8(q, cx.plan, &tr, &dsts, cx.cap),
                    None => pack_dense(cx.dyg, cx.plan, &tr, &dsts, cx.cap),
                }
            })
            .results;
        let sa = obs::enabled().then(|| {
            obs::span(format!("a2a c{c}"), obs::SpanMeta::stage("a2a").step(cx.kk).chunk(c))
        });
        let inbox = all_to_all(mailbox);
        drop(sa);
        let dyks = group
            .run_phase(|ctx| {
                layout.unit_id(ctx.rank, c).map(|u| {
                    let _ss = obs::enabled().then(|| {
                        obs::span(
                            format!("assemble r{} c{c}", ctx.rank),
                            obs::SpanMeta::stage("assemble")
                                .rank(ctx.rank)
                                .step(cx.kk)
                                .chunk(c),
                        )
                    });
                    // receiver-side integrity on the combine-bwd wire
                    for (src, b) in inbox[ctx.rank].iter().enumerate() {
                        cx.faults.deliver(wire_tick(cx.kk, c, true), src, ctx.rank, b);
                    }
                    let er = layout.units[u].experts.clone();
                    match cx.dy_q {
                        Some(q) => assemble_fp8(
                            &inbox[ctx.rank],
                            cx.plan,
                            er,
                            cx.cap,
                            cx.d,
                            cx.token_owner,
                            q.fmt,
                        ),
                        None => assemble_dense(
                            &inbox[ctx.rank],
                            cx.plan,
                            er,
                            cx.cap,
                            cx.d,
                            cx.token_owner,
                        ),
                    }
                })
            })
            .results;
        combine_bwd_s += tc.elapsed().as_secs_f64();

        // ---- expert backward: dgrad + wgrad on the rank's share ----
        let te = Instant::now();
        let ph = group.run_phase(|ctx| {
            dyks[ctx.rank].as_ref().map(|dyk| {
                let _se = obs::enabled().then(|| {
                    obs::span(
                        format!("expert-bwd r{} c{c}", ctx.rank),
                        obs::SpanMeta::stage("expert-bwd")
                            .rank(ctx.rank)
                            .step(cx.kk)
                            .chunk(c),
                    )
                });
                expert_ffn_bwd(dyk, cx.slot, cx.w, ctx.workers)
            })
        });
        for (i, s) in ph.rank_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }
        let round_ebs = ph.results;
        expert_bwd_s += te.elapsed().as_secs_f64();

        // ---- dispatch-bwd: per-rank unpermute into dX partials ----
        let td = Instant::now();
        let parts = group
            .run_phase(|ctx| {
                layout.unit_id(ctx.rank, c).map(|u| {
                    let _sd = obs::enabled().then(|| {
                        obs::span(
                            format!("unpermute r{} c{c}", ctx.rank),
                            obs::SpanMeta::stage("dispatch-bwd")
                                .rank(ctx.rank)
                                .step(cx.kk)
                                .chunk(c),
                        )
                    });
                    let er = layout.units[u].experts.clone();
                    let eb = round_ebs[ctx.rank].as_ref().expect("unit produced a backward");
                    combine(&eb.dxk, cx.plan, er, cx.cap, cx.t, ctx.workers)
                })
            })
            .results;
        dispatch_bwd_s += td.elapsed().as_secs_f64();
        for (rk, (p, eb)) in parts.into_iter().zip(round_ebs).enumerate() {
            if let Some(p) = p {
                let u = layout.unit_id(rk, c).expect("partial implies unit");
                partials[u] = Some(p);
                ebs[u] = eb;
            }
        }
    }
    let wall_s = tw.elapsed().as_secs_f64();
    BwdSlotOut {
        partials: partials.into_iter().map(|p| p.expect("every unit yields a partial")).collect(),
        ebs: ebs.into_iter().map(|e| e.expect("every unit yields a backward")).collect(),
        combine_bwd_s,
        expert_bwd_s,
        dispatch_bwd_s,
        // Bulk-synchronous phases are disjoint wall intervals: wall == busy.
        walls: [combine_bwd_s, expert_bwd_s, dispatch_bwd_s],
        rank_expert_s,
        wall_s,
    }
}

/// Overlapped backward schedule — the forward's step graph reversed in
/// meaning but identical in shape: comm lanes pack/assemble gate-scaled
/// dy for chunk k+1 while compute lanes run chunk k's expert backward,
/// and the dX unpermute of chunk k-1 rides the comm lane.
fn bwd_slot_overlap(cx: &BwdCtx, lanes: &Lanes) -> BwdSlotOut {
    let r = lanes.comm.len();
    let layout = cx.layout;
    let n_units = layout.units.len();
    let wire: Vec<Handoff<WireBuf>> = (0..r * n_units).map(|_| Handoff::new()).collect();
    let dyk_h: Vec<Handoff<RankLocalBatch>> = (0..n_units).map(|_| Handoff::new()).collect();
    let eb_h: Vec<Handoff<ExpertBwd>> = (0..n_units).map(|_| Handoff::new()).collect();
    let out_h: Vec<Handoff<(Mat, ExpertBwd)>> = (0..n_units).map(|_| Handoff::new()).collect();

    let mut g = StepGraph::new(lanes.n_lanes);
    let mut kinds: Vec<(StepKind, usize)> = Vec::new();
    let mut asm_id: Vec<Option<StepId>> = vec![None; n_units];
    let mut ffn_id: Vec<Option<StepId>> = vec![None; n_units];

    // Same round structure as the forward graph; stage meanings reversed.
    for c in 0..=layout.c_max {
        if c < layout.c_max {
            let dsts_c = chunk_dsts(layout, c, r);
            let unit_ids: Vec<Option<usize>> = (0..r).map(|rk| layout.unit_id(rk, c)).collect();
            let packs: Vec<StepId> = (0..r)
                .map(|src| {
                    let (dsts, units) = (dsts_c.clone(), unit_ids.clone());
                    let tr = part_range(cx.tok_part, src);
                    let wire = &wire;
                    let id = g.add_with_meta(
                        lanes.comm[src],
                        &[],
                        format!("pack r{src} c{c}"),
                        obs::SpanMeta::stage("pack").rank(src).step(cx.kk).chunk(c),
                        move || {
                            let bufs = match cx.dy_q {
                                Some(q) => pack_fp8(q, cx.plan, &tr, &dsts, cx.cap),
                                None => pack_dense(cx.dyg, cx.plan, &tr, &dsts, cx.cap),
                            };
                            for (dst, buf) in bufs.into_iter().enumerate() {
                                if let Some(u) = units[dst] {
                                    wire[src * n_units + u].put(buf);
                                }
                            }
                        },
                    );
                    kinds.push((StepKind::Pack, src));
                    id
                })
                .collect();
            for rk in 0..r {
                if let Some(u) = unit_ids[rk] {
                    let er = layout.units[u].experts.clone();
                    let (wire, dyk_h) = (&wire, &dyk_h);
                    let label = format!("assemble r{rk} c{c}");
                    let meta = obs::SpanMeta::stage("assemble").rank(rk).step(cx.kk).chunk(c);
                    let id = g.add_with_meta(lanes.comm[rk], &packs, label, meta, move || {
                        let inbox: Vec<WireBuf> =
                            (0..r).map(|src| wire[src * n_units + u].take()).collect();
                        // receiver-side integrity, same tick coordinate
                        // as the serialized schedule
                        for (src, b) in inbox.iter().enumerate() {
                            cx.faults.deliver(wire_tick(cx.kk, c, true), src, rk, b);
                        }
                        let b = match cx.dy_q {
                            Some(q) => assemble_fp8(
                                &inbox,
                                cx.plan,
                                er,
                                cx.cap,
                                cx.d,
                                cx.token_owner,
                                q.fmt,
                            ),
                            None => {
                                assemble_dense(&inbox, cx.plan, er, cx.cap, cx.d, cx.token_owner)
                            }
                        };
                        dyk_h[u].put(b);
                    });
                    kinds.push((StepKind::Assemble, rk));
                    asm_id[u] = Some(id);
                }
            }
            for rk in 0..r {
                if let Some(u) = unit_ids[rk] {
                    let (dyk_h, eb_h) = (&dyk_h, &eb_h);
                    let threads = lanes.compute_budget[rk];
                    let dep = asm_id[u].expect("expert-bwd follows its unit's assemble");
                    let label = format!("expert-bwd r{rk} c{c}");
                    let meta =
                        obs::SpanMeta::stage("expert-bwd").rank(rk).step(cx.kk).chunk(c);
                    let id = g.add_with_meta(lanes.compute[rk], &[dep], label, meta, move || {
                        let dyk = dyk_h[u].take();
                        eb_h[u].put(expert_ffn_bwd(&dyk, cx.slot, cx.w, threads));
                    });
                    kinds.push((StepKind::Ffn, rk));
                    ffn_id[u] = Some(id);
                }
            }
        }
        if c >= 1 {
            let cc = c - 1;
            for rk in 0..r {
                if let Some(u) = layout.unit_id(rk, cc) {
                    let er = layout.units[u].experts.clone();
                    let (eb_h, out_h) = (&eb_h, &out_h);
                    let dep = ffn_id[u].expect("unpermute follows its unit's expert backward");
                    let label = format!("unpermute r{rk} c{cc}");
                    let meta =
                        obs::SpanMeta::stage("dispatch-bwd").rank(rk).step(cx.kk).chunk(cc);
                    g.add_with_meta(lanes.comm[rk], &[dep], label, meta, move || {
                        let eb = eb_h[u].take();
                        let p = combine(&eb.dxk, cx.plan, er, cx.cap, cx.t, 1);
                        out_h[u].put((p, eb));
                    });
                    kinds.push((StepKind::Combine, rk));
                }
            }
        }
    }
    debug_assert_eq!(kinds.len(), g.n_steps());

    let times = g.run();
    let (mut combine_bwd_s, mut expert_bwd_s, mut dispatch_bwd_s) = (0.0, 0.0, 0.0);
    let mut rank_expert_s = vec![0.0f64; r];
    let mut wall_s = 0.0f64;
    for st in &times {
        let (kind, rk) = kinds[st.id];
        match kind {
            StepKind::Pack | StepKind::Assemble => combine_bwd_s += st.dur_s(),
            StepKind::Ffn => {
                expert_bwd_s += st.dur_s();
                rank_expert_s[rk] += st.dur_s();
            }
            StepKind::Combine => dispatch_bwd_s += st.dur_s(),
        }
        wall_s = wall_s.max(st.end_s);
    }
    let walls = step_walls(&times, &kinds);
    let (partials, ebs) = out_h.iter().map(|h| h.take()).unzip();
    BwdSlotOut {
        partials,
        ebs,
        combine_bwd_s,
        expert_bwd_s,
        dispatch_bwd_s,
        walls,
        rank_expert_s,
        wall_s,
    }
}

/// Run the MoE backward sharded across `cfg.ranks` simulated ranks — the
/// forward pipeline reversed, reusing the same rank group and wire:
///
/// ```text
/// gate-scale dy (+ Q(dy) on the Fp8Flow wire)
///   → pack per token-owner rank → all-to-all → assemble per expert rank
///     (the combine-bwd a2a: same routing as the fwd dispatch)
///   → per-rank expert backward (dgrad + wgrad on its worker share)
///   → per-rank unpermute → serving-unit reduce into the token shards
///     (the dispatch-bwd direction; dX rides in accumulator precision)
/// ```
///
/// Chunking and overlap mirror [`ep_forward`] exactly (same unit layout,
/// same step graph with the stage meanings reversed).
///
/// Bit-identical to the single-rank [`crate::moe::backward::moe_backward`]
/// for any rank count, chunk count and overlap flag
/// (`tests/prop_ep_shard.rs`): per-expert math reads only that expert's
/// rows, the UE8M0 sidecar reproduces po2 scales exactly, each expert's
/// weight gradient is owned by exactly one unit, and per-slot each token
/// receives at most one dX row.
pub fn ep_backward(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
) -> EpBackward {
    ep_backward_with_faults(stash, w, dy, cfg, &FaultPlan::none())
}

/// [`ep_backward`] under a seeded [`FaultPlan`] — the backward mirror of
/// [`ep_forward_with_faults`]: corrupted combine-bwd messages are
/// detected by the per-buffer checksums and recovered bitwise, so the
/// gradients equal the fault-free run for any plan.
pub fn ep_backward_with_faults(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
    faults: &FaultPlan,
) -> EpBackward {
    let t = dy.rows;
    let d = dy.cols;
    let e = w.raw.n_experts();
    let r = cfg.ranks;
    assert!(r >= 1, "need at least one rank");
    assert!(e >= r, "cannot shard {e} experts across {r} ranks");
    assert!(cfg.chunks >= 1, "need at least one pipeline chunk");
    assert_eq!(cfg.capacity, stash.capacity, "config/stash capacity mismatch");
    assert_eq!(cfg.top_k, stash.top_k(), "config/stash top_k mismatch");
    assert_eq!((t, d), (stash.y.rows, stash.y.cols), "dy must match the forward output");
    let total_workers = if cfg.threads == 0 { exec::threads() } else { cfg.threads };
    let ex_part = Partition::even(e, r);
    let tok_part = Partition::even(t, r);
    let token_owner = owner_map(&tok_part, t);
    let layout = ChunkLayout::new(&ex_part, e, cfg.chunks);
    let group = (!cfg.overlap).then(|| RankGroup::new(r, total_workers));
    let lanes = cfg.overlap.then(|| Lanes::new(r, total_workers));
    let cap = cfg.capacity;

    let mut dx = Mat::zeros(t, d);
    let mut dw1: Vec<Mat> = w.raw.w1.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw3: Vec<Mat> = w.raw.w3.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut dw2: Vec<Mat> = w.raw.w2.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut stats = BwdStats::default();
    let mut stages = BwdStageTimes::default();
    let mut rank_expert_s = vec![0.0f64; r];
    let mut walls = [0.0f64; 3];
    let mut pipeline_wall_s = 0.0f64;
    let mut slot_wall_s = Vec::with_capacity(stash.slots.len());
    let (mut dy_payload_b, mut dy_sidecar_b, mut dy_bufs, mut dx_b) = (0usize, 0, 0, 0usize);

    for (kk, slot) in stash.slots.iter().enumerate() {
        let plan = &slot.plan;
        let serving = serving_map(plan, &layout.unit_of_expert, cap, t);

        // Gate-scale + optional Q(dy): once per slot, outside the chunk
        // pipeline in both schedules. Row-independent, so quantizing per
        // token-owner rank would be bit-identical; run it once with the
        // full budget. One cast per slot whatever C is — the chunk-
        // invariance the lint cross-check pins.
        let tg = Instant::now();
        let sg = obs::enabled().then(|| {
            obs::span(format!("gate-scale k{kk}"), obs::SpanMeta::stage("combine-bwd").step(kk))
        });
        let dyg = scale_by_gates_with_threads(dy, &stash.routing, kk, total_workers);
        drop(sg);
        let dy_q = if w.recipe == Recipe::Fp8Flow {
            stats.casts += 1;
            obs::count(Counter::CastsBwd, 1); // Fp8Flow's one Q(dy) per slot
            let sq = obs::enabled().then(|| {
                obs::span(format!("qdy k{kk}"), obs::SpanMeta::stage("quant").step(kk))
            });
            let q = quantize_rowwise_with_threads(
                &dyg,
                Fp8Format::E4M3,
                ScaleMode::Po2,
                total_workers,
            );
            drop(sq);
            Some(q)
        } else {
            None
        };
        let preamble = tg.elapsed().as_secs_f64();
        stages.combine_bwd_s += preamble;

        // Analytic wire accounting, outside the timers (same reasoning
        // as the forward).
        let (p_b, s_b, b_b) = wire_accounting(
            plan,
            &tok_part,
            &layout,
            cap,
            r,
            d,
            dy_q.as_ref().map(|_| n_tiles(d)),
        );
        dy_payload_b += p_b;
        dy_sidecar_b += s_b;
        dy_bufs += b_b;
        dx_b += plan.iter().filter(|&&s| s >= 0).count() * d * 2;

        let cx = BwdCtx {
            dyg: &dyg,
            dy_q: dy_q.as_ref(),
            w,
            slot,
            plan,
            layout: &layout,
            tok_part: &tok_part,
            token_owner: &token_owner,
            cap,
            t,
            d,
            kk,
            faults,
        };
        let out = match (&group, &lanes) {
            (Some(g), _) => bwd_slot_serial(&cx, g),
            (_, Some(l)) => bwd_slot_overlap(&cx, l),
            _ => unreachable!("exactly one schedule is constructed"),
        };
        stages.combine_bwd_s += out.combine_bwd_s;
        stages.expert_bwd_s += out.expert_bwd_s;
        stages.dispatch_bwd_s += out.dispatch_bwd_s;
        walls[0] += out.walls[0] + preamble;
        walls[1] += out.walls[1];
        walls[2] += out.walls[2];
        for (i, s) in out.rank_expert_s.iter().enumerate() {
            rank_expert_s[i] += s;
        }

        // Weight gradients stay with their expert's owning unit; the
        // global Vec is just the unit union (ascending unit = ascending
        // expert order, one owner per expert ⇒ bitwise the single-rank
        // accumulation).
        for eb in &out.ebs {
            stats.add(eb.stats);
            for (lx, gr) in eb.grads.iter().enumerate() {
                let ge = eb.experts.start + lx;
                mat_add_assign(&mut dw1[ge], &gr.dw1);
                mat_add_assign(&mut dw3[ge], &gr.dw3);
                mat_add_assign(&mut dw2[ge], &gr.dw2);
            }
        }

        // Serving-unit reduce into the token shards — same bit-exactness
        // argument as the forward combine reduce.
        let tr_ = Instant::now();
        let sv = obs::enabled().then(|| {
            obs::span(format!("reduce k{kk}"), obs::SpanMeta::stage("dispatch-bwd").step(kk))
        });
        reduce_serving(&mut dx, &out.partials, &serving, &tok_part, d, None);
        drop(sv);
        let red = tr_.elapsed().as_secs_f64();
        stages.dispatch_bwd_s += red;
        walls[2] += red;
        let wall = out.wall_s + red;
        pipeline_wall_s += wall;
        slot_wall_s.push(wall);
    }

    EpBackward {
        grads: MoeGrads { dx, dw1, dw3, dw2, d_router: None, stats, stages },
        ranks: r,
        chunks: layout.c_max,
        overlap: cfg.overlap,
        combine_bwd_wall_s: walls[0],
        expert_bwd_wall_s: walls[1],
        dispatch_bwd_wall_s: walls[2],
        pipeline_wall_s,
        slot_wall_s,
        rank_expert_s,
        dy_payload_bytes: dy_payload_b,
        dy_sidecar_bytes: dy_sidecar_b,
        dy_buffers: dy_bufs,
        dx_bytes: dx_b,
    }
}

/// [`ep_backward`] plus the routing path: the gate/aux gradients are
/// dense f32 and replicated (every rank computes the identical result in
/// a real deployment; here they are computed once), so adding them after
/// the sharded expert backward is bitwise the single-rank
/// [`crate::moe::backward::moe_backward_with_router`].
pub fn ep_backward_with_router(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
    aux_coef: f32,
) -> EpBackward {
    ep_backward_with_router_faults(stash, w, dy, cfg, aux_coef, &FaultPlan::none())
}

/// [`ep_backward_with_router`] under a seeded [`FaultPlan`] (the router
/// path is dense-replicated and never touches the wire, so only the
/// sharded expert backward sees the faults).
pub fn ep_backward_with_router_faults(
    stash: &FwdStash,
    w: &PreparedWeights,
    dy: &Mat,
    cfg: &EpConfig,
    aux_coef: f32,
    faults: &FaultPlan,
) -> EpBackward {
    let mut out = ep_backward_with_faults(stash, w, dy, cfg, faults);
    let rb = router_backward_from_stash(stash, w, dy, aux_coef);
    mat_add_assign(&mut out.grads.dx, &rb.dx);
    out.grads.d_router = Some(rb.d_router);
    out
}

/// One **EP-sharded native training step**: the trainer's forward (whose
/// stash is bitwise the sharded forward's, PR 2's invariance theorem),
/// then per-rank backward → gradient reduce across the
/// [`crate::cluster::rank::RankGroup`] ([`ep_backward_with_router`]: the
/// dispatch-bwd serving-unit reduce for dX, the unit union for the
/// expert weight grads, the replicated dense router path), then the
/// **replicated optimizer step** — deterministic f32 over identical
/// reduced gradients, so executing it once stands in for R identical
/// executions — ending in the masters→FP8 weight requantization.
///
/// Bit-identical to [`NativeTrainer::step_batch`] at `ranks = 1` for any
/// rank count (`tests/prop_train.rs`): the two paths share the step core
/// and differ only in the MoE backward closure, whose EP invariance PR 3
/// already proves.
pub fn ep_train_step(tr: &mut NativeTrainer, tokens: &[i32]) -> TrainMetrics {
    let cfg = EpConfig::serial(tr.cfg.ranks, tr.cfg.top_k, tr.cfg.capacity, tr.cfg.threads);
    tr.step_with_backward(tokens, move |stash, w, dy, aux_coef| {
        ep_backward_with_router(stash, w, dy, &cfg, aux_coef).grads
    })
}

/// [`ep_train_step`] under a seeded [`FaultPlan`]: the combine-bwd wire
/// runs through the checksummed delivery path, so an injected flip or
/// drop is recovered and the step stays bitwise equal to the fault-free
/// step — the property the chaos driver's train matrix asserts.
pub fn ep_train_step_with_faults(
    tr: &mut NativeTrainer,
    tokens: &[i32],
    faults: &FaultPlan,
) -> TrainMetrics {
    let cfg = EpConfig::serial(tr.cfg.ranks, tr.cfg.top_k, tr.cfg.capacity, tr.cfg.threads);
    tr.step_with_backward(tokens, move |stash, w, dy, aux_coef| {
        ep_backward_with_router_faults(stash, w, dy, &cfg, aux_coef, faults).grads
    })
}

// ---------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------

/// Serving unit per token for one slot's plan (`usize::MAX` = dropped by
/// capacity). Shared by the forward combine reduce and the backward
/// dispatch-bwd reduce — both read exactly one partial per served token.
fn serving_map(
    plan: &[i64],
    unit_of_expert: &[usize],
    capacity: usize,
    n_tokens: usize,
) -> Vec<usize> {
    let mut serving = vec![usize::MAX; n_tokens];
    for (gd, &src) in plan.iter().enumerate() {
        if src >= 0 {
            serving[src as usize] = unit_of_expert[gd / capacity];
        }
    }
    serving
}

/// Add each served token's single nonzero partial row into its token
/// shard (gated in the forward, plain in the backward), one task per
/// shard over disjoint output rows.
fn reduce_serving(
    out: &mut Mat,
    partials: &[Mat],
    serving: &[usize],
    tok_part: &Partition,
    d: usize,
    gates: Option<(&Routing, usize)>,
) {
    if obs::enabled() {
        // One BF16-accounted partial row is reduced per served token —
        // the measured counterpart of the drivers' `combine_bytes` /
        // `dx_bytes` analytic accounting.
        let served = serving.iter().filter(|&&su| su != usize::MAX).count();
        obs::count(Counter::CombineBytes, (served * d * 2) as u64);
    }
    let tasks: Vec<_> = exec::split_parts(tok_part, d, &mut out.data)
        .into_iter()
        .zip(tok_part.ranges())
        .collect();
    exec::run_tasks(tasks, |(rows, trange)| {
        for tt in trange.clone() {
            let su = serving[tt];
            if su == usize::MAX {
                continue; // dropped by capacity: the row stays zero
            }
            let o = (tt - trange.start) * d;
            let p = &partials[su].data;
            match gates {
                Some((routing, kk)) => {
                    let g = routing.gates[tt][kk];
                    for j in 0..d {
                        rows[o + j] += g * p[tt * d + j];
                    }
                }
                None => {
                    for j in 0..d {
                        rows[o + j] += p[tt * d + j];
                    }
                }
            }
        }
    });
}

/// Item → owning rank, from a partition (tokens or experts).
fn owner_map(part: &Partition, n_items: usize) -> Vec<usize> {
    let mut owner = vec![0usize; n_items];
    for (r, range) in part.ranges().enumerate() {
        for i in range {
            owner[i] = r;
        }
    }
    owner
}

/// Range of part `i`, or empty when the partition has fewer parts than
/// ranks (more ranks than tokens).
fn part_range(p: &Partition, i: usize) -> Range<usize> {
    if i < p.len() {
        p.range(i)
    } else {
        0..0
    }
}

/// Rows this source rank ships into one destination's expert segment
/// (= the exact send-buffer size, computed before packing).
fn sent_rows(plan: &[i64], dr: &Range<usize>, capacity: usize, tok: &Range<usize>) -> usize {
    plan[dr.start * capacity..dr.end * capacity]
        .iter()
        .filter(|&&src| src >= 0 && tok.contains(&(src as usize)))
        .count()
}

/// Analytic wire totals for one slot: payload/sidecar bytes and buffer
/// count over every src-rank → dst-unit pair. Bytes are chunk-invariant
/// (the same real rows ship whatever C is); the buffer count scales with
/// the pair count — chunking buys overlap by splitting the collective
/// into more, smaller synchronization rounds.
fn wire_accounting(
    plan: &[i64],
    tok_part: &Partition,
    layout: &ChunkLayout,
    capacity: usize,
    n_ranks: usize,
    cols: usize,
    fp8_tiles: Option<usize>,
) -> (usize, usize, usize) {
    let (mut payload, mut sidecar, mut bufs) = (0usize, 0usize, 0usize);
    for src in 0..n_ranks {
        let tr = part_range(tok_part, src);
        for unit in &layout.units {
            let n = sent_rows(plan, &unit.experts, capacity, &tr);
            match fp8_tiles {
                Some(tpr) => {
                    payload += n * cols;
                    sidecar += n * tpr;
                    bufs += 2;
                }
                None => {
                    payload += n * cols * 2;
                    bufs += 1;
                }
            }
        }
    }
    (payload, sidecar, bufs)
}

/// Pack one source rank's FP8 sends: for each destination expert range,
/// its tokens' code rows (ascending plan order) plus the UE8M0 sidecar
/// as a second buffer. An empty range yields an empty (but present)
/// buffer, keeping the mailbox square across chunk rounds.
fn pack_fp8(
    xq: &Fp8Tensor,
    plan: &[i64],
    tok: &Range<usize>,
    dsts: &[Range<usize>],
    capacity: usize,
) -> Vec<WireBuf> {
    let h = xq.cols;
    let tpr = n_tiles(h);
    assert!(!xq.sexp.is_empty(), "FP8 wire needs po2 scale exponents");
    dsts.iter()
        .map(|dr| {
            // size the buffers exactly up front: reallocation memmoves
            // would otherwise be charged to the timed dispatch stage
            let n_rows = sent_rows(plan, dr, capacity, tok);
            let mut codes = Vec::with_capacity(n_rows * h);
            let mut sidecar = Vec::with_capacity(n_rows * tpr);
            for gd in dr.start * capacity..dr.end * capacity {
                let src = plan[gd];
                if src >= 0 && tok.contains(&(src as usize)) {
                    let s = src as usize;
                    codes.extend_from_slice(&xq.data[s * h..(s + 1) * h]);
                    for k in 0..tpr {
                        let e = xq.sexp[s * tpr + k];
                        // Outside UE8M0's exponent range the sidecar would
                        // saturate and silently break the bit-identity
                        // contract — fail loudly, in release builds too.
                        assert!(
                            (-(ue8m0::BIAS)..=(255 - ue8m0::BIAS)).contains(&e),
                            "po2 scale exponent {e} not UE8M0-representable"
                        );
                        sidecar.push(ue8m0::from_exponent(e));
                    }
                }
            }
            // Counters read the *actual* packed buffers — an independent
            // measurement the analytic `wire_accounting` is checked
            // against (live cross-check + `tests/prop_obs.rs`). Empty
            // `dr` means "no unit at this round for that rank": no
            // buffer ships, so it must not count.
            if obs::enabled() && !dr.is_empty() {
                obs::count(Counter::WirePayloadBytes, codes.len() as u64);
                obs::count(Counter::WireSidecarBytes, sidecar.len() as u64);
                obs::count(Counter::WireBuffers, 2);
            }
            WireBuf::Fp8 { codes, sidecar }
        })
        .collect()
}

/// Pack one source rank's dense (BF16-wire) sends.
fn pack_dense(
    x: &Mat,
    plan: &[i64],
    tok: &Range<usize>,
    dsts: &[Range<usize>],
    capacity: usize,
) -> Vec<WireBuf> {
    let h = x.cols;
    dsts.iter()
        .map(|dr| {
            let mut rows = Vec::with_capacity(sent_rows(plan, dr, capacity, tok) * h);
            for gd in dr.start * capacity..dr.end * capacity {
                let src = plan[gd];
                if src >= 0 && tok.contains(&(src as usize)) {
                    rows.extend_from_slice(x.row(src as usize));
                }
            }
            // BF16-accounted dense wire: 2 bytes per f32-carried element,
            // one buffer per src→dst-unit pair (see pack_fp8's note).
            if obs::enabled() && !dr.is_empty() {
                obs::count(Counter::WirePayloadBytes, (rows.len() * 2) as u64);
                obs::count(Counter::WireBuffers, 1);
            }
            WireBuf::Dense(rows)
        })
        .collect()
}

/// Assemble one destination unit's `[E_unit·capacity, d]` FP8 batch from
/// its received buffers. Padding rows stay zero codes with scale 1
/// (= 2^0) — exactly `permute_pad_fp8`'s initialization, which the
/// bit-identity contract relies on.
fn assemble_fp8(
    inbox: &[WireBuf],
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    cols: usize,
    token_owner: &[usize],
    fmt: Fp8Format,
) -> RankLocalBatch {
    let tpr = n_tiles(cols);
    let rows = experts.len() * capacity;
    let mut data = vec![0u8; rows * cols];
    let mut scales = vec![1.0f32; rows * tpr];
    let mut sexp = vec![0i32; rows * tpr];
    let mut cur = vec![0usize; inbox.len()];
    for (ld, gd) in (experts.start * capacity..experts.end * capacity).enumerate() {
        let src = plan[gd];
        if src < 0 {
            continue;
        }
        let s_rank = token_owner[src as usize];
        let WireBuf::Fp8 { codes, sidecar } = &inbox[s_rank] else {
            panic!("FP8 assemble received a dense wire buffer");
        };
        let c = cur[s_rank];
        data[ld * cols..(ld + 1) * cols].copy_from_slice(&codes[c * cols..(c + 1) * cols]);
        for k in 0..tpr {
            let b = sidecar[c * tpr + k];
            // scale == 2^sexp (po2 contract): decoding the sidecar byte
            // reproduces the original f32 scale bitwise
            scales[ld * tpr + k] = ue8m0::decode(b);
            sexp[ld * tpr + k] = ue8m0::exponent(b);
        }
        cur[s_rank] += 1;
    }
    let payload = WirePayload::Fp8(Fp8Tensor {
        rows,
        cols,
        fmt,
        mode: ScaleMode::Po2,
        layout: TileLayout::RowWise,
        data,
        scales,
        sexp,
    });
    RankLocalBatch { experts, capacity, payload }
}

/// Assemble one destination unit's dense batch.
fn assemble_dense(
    inbox: &[WireBuf],
    plan: &[i64],
    experts: Range<usize>,
    capacity: usize,
    cols: usize,
    token_owner: &[usize],
) -> RankLocalBatch {
    let rows = experts.len() * capacity;
    let mut m = Mat::zeros(rows, cols);
    let mut cur = vec![0usize; inbox.len()];
    for (ld, gd) in (experts.start * capacity..experts.end * capacity).enumerate() {
        let src = plan[gd];
        if src < 0 {
            continue;
        }
        let s_rank = token_owner[src as usize];
        let WireBuf::Dense(buf) = &inbox[s_rank] else {
            panic!("dense assemble received an FP8 wire buffer");
        };
        let c = cur[s_rank];
        m.data[ld * cols..(ld + 1) * cols].copy_from_slice(&buf[c * cols..(c + 1) * cols]);
        cur[s_rank] += 1;
    }
    RankLocalBatch { experts, capacity, payload: WirePayload::Dense(m) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::{moe_forward, MoeWeights};
    use crate::util::prop::assert_mat_bits_eq;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Mat, MoeWeights) {
        let mut rng = Rng::seed_from(seed);
        let (t, d, h, e) = (64, 64, 48, 4);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        (x, w)
    }

    #[test]
    fn sharded_matches_single_rank_all_recipes() {
        let (x, w) = setup(21);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let reference = moe_forward(&x, &pw, 2, 24);
            for ranks in [1usize, 2, 4] {
                let cfg = EpConfig::serial(ranks, 2, 24, 0);
                let out = ep_forward(&x, &pw, &cfg);
                assert_mat_bits_eq(&out.y, &reference.y, &format!("{recipe:?} R={ranks}"));
                assert_eq!(out.aux_loss.to_bits(), reference.aux_loss.to_bits());
            }
        }
    }

    #[test]
    fn chunked_and_overlapped_match_single_rank_all_recipes() {
        let (x, w) = setup(31);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let reference = moe_forward(&x, &pw, 2, 24);
            for chunks in [2usize, 3] {
                for overlap in [false, true] {
                    let cfg = EpConfig::serial(2, 2, 24, 0).with_pipeline(chunks, overlap);
                    let out = ep_forward(&x, &pw, &cfg);
                    let tag = format!("{recipe:?} C={chunks} overlap={overlap}");
                    assert_mat_bits_eq(&out.y, &reference.y, &tag);
                    // 4 experts over 2 ranks = 2 per rank: C clamps to 2
                    assert_eq!(out.chunks, chunks.min(2), "{tag}");
                    assert_eq!(out.overlap, overlap, "{tag}");
                }
            }
        }
    }

    #[test]
    fn ragged_chunk_count_clamps_to_expert_share() {
        // 4 experts over 2 ranks = 2 experts/rank: asking for 8 chunks
        // must clamp to 2 per rank, not create empty units.
        let (x, w) = setup(32);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let reference = moe_forward(&x, &pw, 2, 24);
        let cfg = EpConfig::serial(2, 2, 24, 0).with_pipeline(8, true);
        let out = ep_forward(&x, &pw, &cfg);
        assert_eq!(out.chunks, 2);
        assert_mat_bits_eq(&out.y, &reference.y, "ragged C clamp");
    }

    #[test]
    fn wire_bytes_are_chunk_invariant_but_buffers_scale() {
        let (x, w) = setup(33);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let c1 = ep_forward(&x, &pw, &EpConfig::serial(2, 1, 32, 2));
        let c2 = ep_forward(&x, &pw, &EpConfig::serial(2, 1, 32, 2).with_pipeline(2, false));
        // same real rows ship whatever C is
        assert_eq!(c1.dispatch_payload_bytes, c2.dispatch_payload_bytes);
        assert_eq!(c1.dispatch_sidecar_bytes, c2.dispatch_sidecar_bytes);
        assert_eq!(c1.combine_bytes, c2.combine_bytes);
        // but the collective splits into C× the src→dst-unit pairs
        assert_eq!(c2.dispatch_buffers, 2 * c1.dispatch_buffers);
    }

    #[test]
    fn fp8_wire_is_lighter_and_doubles_buffer_count() {
        let (x, w) = setup(22);
        let cfg = EpConfig::serial(2, 1, 32, 2);
        let flow = ep_forward(&x, &PreparedWeights::new(w.clone(), Recipe::Fp8Flow), &cfg);
        let bf16 = ep_forward(&x, &PreparedWeights::new(w, Recipe::Bf16), &cfg);
        // same real rows shipped → FP8 payload is exactly half the BF16 bytes
        assert_eq!(flow.dispatch_payload_bytes * 2, bf16.dispatch_payload_bytes);
        assert!(flow.dispatch_sidecar_bytes > 0);
        assert_eq!(bf16.dispatch_sidecar_bytes, 0);
        // two-buffer model: FP8 ships 2 buffers per src→dst pair, BF16 one
        assert_eq!(flow.dispatch_buffers, 2 * bf16.dispatch_buffers);
        assert_eq!(bf16.dispatch_buffers, 2 * 2); // R² pairs, one slot, C=1
        // combine stays BF16 in both recipes
        assert_eq!(flow.combine_bytes, bf16.combine_bytes);
    }

    #[test]
    fn stage_timers_are_populated() {
        let (x, w) = setup(23);
        let cfg = EpConfig::serial(2, 1, 32, 2);
        let out = ep_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), &cfg);
        assert!(out.stages.route_s > 0.0);
        assert!(out.stages.quant_s > 0.0);
        assert!(out.stages.dispatch_s > 0.0);
        assert!(out.stages.expert_s > 0.0);
        assert!(out.stages.combine_s > 0.0);
        assert_eq!(out.rank_expert_s.len(), 2);
        assert!(out.stages.total_s() >= out.stages.expert_s);
        assert!(out.pipeline_wall_s > 0.0);
        assert_eq!(out.slot_wall_s.len(), 1);
        let j = out.to_json().render();
        assert!(j.contains("\"dispatch_ms\""), "{j}");
        assert!(j.contains("\"pipeline_wall_ms\""), "{j}");
        assert!(j.contains("\"overlap\""), "{j}");
    }

    #[test]
    fn overlapped_timers_are_populated_too() {
        let (x, w) = setup(34);
        let cfg = EpConfig::serial(2, 2, 24, 4).with_pipeline(2, true);
        let out = ep_forward(&x, &PreparedWeights::new(w, Recipe::Fp8Flow), &cfg);
        assert!(out.stages.dispatch_s > 0.0);
        assert!(out.stages.expert_s > 0.0);
        assert!(out.stages.combine_s > 0.0);
        assert!(out.pipeline_wall_s > 0.0);
        assert_eq!(out.slot_wall_s.len(), 2);
        assert!(out.rank_expert_s.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn recorded_counters_match_analytic_wire_accounting() {
        let (x, w) = setup(40);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        for (chunks, overlap) in [(1, false), (2, false), (2, true)] {
            let rec = obs::Recorder::new(1);
            let out = {
                let _g = obs::install(rec.clone());
                ep_forward(&x, &pw, &EpConfig::serial(2, 2, 24, 2).with_pipeline(chunks, overlap))
            };
            let tag = format!("C={chunks} overlap={overlap}");
            let t = rec.totals();
            assert_eq!(
                t[Counter::WirePayloadBytes as usize] as usize,
                out.dispatch_payload_bytes,
                "{tag} payload"
            );
            assert_eq!(
                t[Counter::WireSidecarBytes as usize] as usize,
                out.dispatch_sidecar_bytes,
                "{tag} sidecar"
            );
            assert_eq!(
                t[Counter::WireBuffers as usize] as usize,
                out.dispatch_buffers,
                "{tag} buffers"
            );
            assert_eq!(
                t[Counter::CombineBytes as usize] as usize,
                out.combine_bytes,
                "{tag} combine"
            );
            // Fp8Flow forward: exactly one explicit cast (the entry quant)
            assert_eq!(t[Counter::CastsFwd as usize], 1, "{tag}");
            assert_eq!(t[Counter::CastsBwd as usize], 0, "{tag}");
            // spans cover every forward stage
            let spans = rec.spans();
            let stages: Vec<&str> = spans.iter().map(|s| s.meta.stage).collect();
            for st in ["route", "quant", "pack", "assemble", "ffn", "combine"] {
                assert!(stages.contains(&st), "{tag}: missing stage {st}");
            }
            if !overlap {
                assert!(stages.contains(&"a2a"), "{tag}: serialized trace has a2a spans");
            }
        }
    }

    #[test]
    fn stage_walls_are_populated_and_bounded_by_busy() {
        let (x, w) = setup(41);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let serial = ep_forward(&x, &pw, &EpConfig::serial(2, 2, 24, 4).with_pipeline(2, false));
        // serialized: wall == busy by construction
        assert_eq!(serial.dispatch_wall_s, serial.stages.dispatch_s);
        assert_eq!(serial.expert_wall_s, serial.stages.expert_s);
        assert!(serial.combine_wall_s > 0.0);
        let over = ep_forward(&x, &pw, &EpConfig::serial(2, 2, 24, 4).with_pipeline(2, true));
        // overlapped: interval union can never exceed summed busy
        let eps = 1e-9;
        assert!(over.dispatch_wall_s > 0.0);
        assert!(over.dispatch_wall_s <= over.stages.dispatch_s + eps);
        assert!(over.expert_wall_s <= over.stages.expert_s + eps);
        assert!(over.combine_wall_s <= over.stages.combine_s + eps);
        let j = over.to_json().render();
        assert!(j.contains("\"dispatch_wall_ms\""), "{j}");
        assert!(j.contains("\"expert_wall_ms\""), "{j}");
        assert!(j.contains("\"combine_wall_ms\""), "{j}");
    }

    #[test]
    fn backward_counters_and_walls() {
        use crate::moe::backward::forward_stash;
        let (x, w) = setup(42);
        let mut rng = Rng::seed_from(43);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let stash = forward_stash(&x, &pw, 2, 24);
        let rec = obs::Recorder::new(1);
        let out = {
            let _g = obs::install(rec.clone());
            ep_backward(&stash, &pw, &dy, &EpConfig::serial(2, 2, 24, 2).with_pipeline(2, true))
        };
        let t = rec.totals();
        assert_eq!(t[Counter::WirePayloadBytes as usize] as usize, out.dy_payload_bytes);
        assert_eq!(t[Counter::WireSidecarBytes as usize] as usize, out.dy_sidecar_bytes);
        assert_eq!(t[Counter::WireBuffers as usize] as usize, out.dy_buffers);
        assert_eq!(t[Counter::CombineBytes as usize] as usize, out.dx_bytes);
        // Fp8Flow backward: one Q(dy) per top-k slot
        assert_eq!(t[Counter::CastsBwd as usize], 2);
        assert!(out.combine_bwd_wall_s > 0.0);
        assert!(out.expert_bwd_wall_s > 0.0);
        assert!(out.dispatch_bwd_wall_s > 0.0);
        let j = out.to_json().render();
        assert!(j.contains("\"combine_bwd_wall_ms\""), "{j}");
    }

    #[test]
    fn more_ranks_than_tokens_still_exact() {
        let mut rng = Rng::seed_from(24);
        let (t, d, h, e) = (3, 32, 16, 4);
        let x = Mat::randn(t, d, 0.5, &mut rng);
        let w = MoeWeights::random(d, h, e, &mut rng);
        let pw = PreparedWeights::new(w, Recipe::Fp8Flow);
        let reference = moe_forward(&x, &pw, 1, 2);
        let out = ep_forward(&x, &pw, &EpConfig::serial(4, 1, 2, 3));
        assert_mat_bits_eq(&out.y, &reference.y, "R>T");
        let out = ep_forward(&x, &pw, &EpConfig::serial(4, 1, 2, 3).with_pipeline(2, true));
        assert_mat_bits_eq(&out.y, &reference.y, "R>T overlapped");
    }

    #[test]
    #[should_panic(expected = "cannot shard")]
    fn more_ranks_than_experts_rejected() {
        let (x, w) = setup(25);
        let pw = PreparedWeights::new(w, Recipe::Bf16);
        ep_forward(&x, &pw, &EpConfig::serial(8, 1, 8, 1));
    }

    #[test]
    #[should_panic(expected = "at least one pipeline chunk")]
    fn zero_chunks_rejected() {
        let (x, w) = setup(25);
        let pw = PreparedWeights::new(w, Recipe::Bf16);
        ep_forward(&x, &pw, &EpConfig::serial(2, 1, 8, 1).with_pipeline(0, false));
    }

    #[test]
    fn sharded_backward_matches_single_rank_all_recipes() {
        use crate::moe::backward::{forward_stash, moe_backward};
        let (x, w) = setup(26);
        let mut rng = Rng::seed_from(27);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        for recipe in [Recipe::Bf16, Recipe::Blockwise, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let stash = forward_stash(&x, &pw, 2, 24);
            let reference = moe_backward(&stash, &pw, &dy);
            for ranks in [1usize, 2, 4] {
                let cfg = EpConfig::serial(ranks, 2, 24, 0);
                let out = ep_backward(&stash, &pw, &dy, &cfg);
                let tag = format!("{recipe:?} R={ranks}");
                assert_mat_bits_eq(&out.grads.dx, &reference.dx, &format!("{tag} dx"));
                for e in 0..w.n_experts() {
                    let g = &out.grads;
                    assert_mat_bits_eq(&g.dw1[e], &reference.dw1[e], &format!("{tag} dw1[{e}]"));
                    assert_mat_bits_eq(&g.dw3[e], &reference.dw3[e], &format!("{tag} dw3[{e}]"));
                    assert_mat_bits_eq(&g.dw2[e], &reference.dw2[e], &format!("{tag} dw2[{e}]"));
                }
                assert_eq!(out.grads.stats, reference.stats, "{tag} cast audit");
            }
        }
    }

    #[test]
    fn chunked_and_overlapped_backward_match_single_rank() {
        use crate::moe::backward::{forward_stash, moe_backward};
        let (x, w) = setup(35);
        let mut rng = Rng::seed_from(36);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        for recipe in [Recipe::Bf16, Recipe::Fp8Flow] {
            let pw = PreparedWeights::new(w.clone(), recipe);
            let stash = forward_stash(&x, &pw, 2, 24);
            let reference = moe_backward(&stash, &pw, &dy);
            for overlap in [false, true] {
                let cfg = EpConfig::serial(2, 2, 24, 0).with_pipeline(2, overlap);
                let out = ep_backward(&stash, &pw, &dy, &cfg);
                let tag = format!("{recipe:?} C=2 overlap={overlap}");
                assert_mat_bits_eq(&out.grads.dx, &reference.dx, &format!("{tag} dx"));
                for e in 0..w.n_experts() {
                    let g = &out.grads;
                    assert_mat_bits_eq(&g.dw2[e], &reference.dw2[e], &format!("{tag} dw2[{e}]"));
                }
                // cast/requant totals are chunk-invariant (lint contract)
                assert_eq!(out.grads.stats, reference.stats, "{tag} cast audit");
                assert!(out.pipeline_wall_s > 0.0, "{tag}");
                assert_eq!(out.slot_wall_s.len(), 2, "{tag}");
                let j = out.to_json().render();
                assert!(j.contains("\"pipeline_wall_ms\""), "{j}");
            }
        }
    }

    #[test]
    fn backward_fp8_wire_accounting() {
        use crate::moe::backward::forward_stash;
        let (x, w) = setup(28);
        let mut rng = Rng::seed_from(29);
        let dy = Mat::randn(x.rows, x.cols, 1.0, &mut rng);
        let cfg = EpConfig::serial(2, 1, 32, 2);
        let pw_f = PreparedWeights::new(w.clone(), Recipe::Fp8Flow);
        let st_f = forward_stash(&x, &pw_f, 1, 32);
        let flow = ep_backward(&st_f, &pw_f, &dy, &cfg);
        let pw_b = PreparedWeights::new(w, Recipe::Bf16);
        let st_b = forward_stash(&x, &pw_b, 1, 32);
        let bf16 = ep_backward(&st_b, &pw_b, &dy, &cfg);
        // same real rows shipped → FP8 dy payload is exactly half the BF16
        // bytes, plus the UE8M0 sidecar in a second buffer per pair
        assert_eq!(flow.dy_payload_bytes * 2, bf16.dy_payload_bytes);
        assert!(flow.dy_sidecar_bytes > 0);
        assert_eq!(bf16.dy_sidecar_bytes, 0);
        assert_eq!(flow.dy_buffers, 2 * bf16.dy_buffers);
        // dX rides in accumulator precision in both recipes
        assert_eq!(flow.dx_bytes, bf16.dx_bytes);
        // and the stage timers are populated
        assert!(flow.grads.stages.combine_bwd_s > 0.0);
        assert!(flow.grads.stages.expert_bwd_s > 0.0);
        assert!(flow.grads.stages.dispatch_bwd_s > 0.0);
        let j = flow.to_json().render();
        assert!(j.contains("\"expert_bwd_ms\""), "{j}");
    }
}
