//! 1F1B pipeline schedule timing (§4.2: experiments use Megatron's
//! 1F1B-overlap-compatible configuration, without comm overlap).
//!
//! Classic 1F1B: steady state interleaves one forward and one backward per
//! stage; total step time ≈ (n_micro + pp − 1) slots where a slot is the
//! per-stage fwd+bwd time of one microbatch, plus the warmup/drain bubble.
//!
//! [`one_f_one_b`] takes the slot as `fwd + bwd` — comm fully on the
//! critical path. [`one_f_one_b_overlap`] splits each direction into a
//! compute and a comm term and hides comm up to `max(comm, compute)` per
//! direction — the double-buffered EP pipeline's steady state
//! ([`crate::cluster::ep_exec`]), whose measured efficiency
//! ([`crate::cluster::sim::ep_overlap_report`]) calibrates how much of
//! that full-hiding assumption the executed step graph actually delivers.

/// Pipeline timing summary (seconds).
#[derive(Clone, Copy, Debug)]
pub struct PipelineTime {
    /// fwd+bwd time of one microbatch on one stage.
    pub slot: f64,
    /// total step wallclock.
    pub step: f64,
    /// bubble fraction (idle / total).
    pub bubble_frac: f64,
}

/// Roll a per-stage per-microbatch slot time up into the 1F1B step.
fn from_slot(slot: f64, pp: usize, n_micro: usize) -> PipelineTime {
    assert!(pp >= 1 && n_micro >= 1);
    // steady-state occupancy: n_micro slots, plus (pp-1) warmup+drain
    let step = slot * (n_micro as f64 + (pp as f64 - 1.0));
    let busy = slot * n_micro as f64;
    PipelineTime { slot, step, bubble_frac: 1.0 - busy / step }
}

/// Compute 1F1B step time given per-stage per-microbatch fwd and bwd times.
pub fn one_f_one_b(fwd: f64, bwd: f64, pp: usize, n_micro: usize) -> PipelineTime {
    from_slot(fwd + bwd, pp, n_micro)
}

/// 1F1B with comm/compute overlap inside each direction: the slot pays
/// `max(compute, comm)` per direction instead of their sum — comm hides
/// behind compute until it *becomes* the bottleneck, at which point the
/// slot is comm-bound and further compute shrink buys nothing. With
/// `overlap = false` this reproduces [`one_f_one_b`] on the summed
/// times exactly.
pub fn one_f_one_b_overlap(
    compute_fwd: f64,
    comm_fwd: f64,
    compute_bwd: f64,
    comm_bwd: f64,
    pp: usize,
    n_micro: usize,
    overlap: bool,
) -> PipelineTime {
    let slot = if overlap {
        compute_fwd.max(comm_fwd) + compute_bwd.max(comm_bwd)
    } else {
        (compute_fwd + comm_fwd) + (compute_bwd + comm_bwd)
    };
    from_slot(slot, pp, n_micro)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_pipeline_no_bubble() {
        let t = one_f_one_b(1.0, 2.0, 1, 16);
        assert_eq!(t.step, 48.0);
        assert_eq!(t.bubble_frac, 0.0);
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        let few = one_f_one_b(1.0, 2.0, 8, 8);
        let many = one_f_one_b(1.0, 2.0, 8, 64);
        assert!(many.bubble_frac < few.bubble_frac);
        assert!((few.bubble_frac - 7.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn deeper_pipeline_larger_bubble() {
        let shallow = one_f_one_b(1.0, 2.0, 8, 64);
        let deep = one_f_one_b(1.0, 2.0, 32, 64);
        assert!(deep.bubble_frac > shallow.bubble_frac);
    }

    #[test]
    fn overlap_off_reproduces_the_legacy_schedule() {
        let legacy = one_f_one_b(3.0 + 1.0, 2.0 + 5.0, 4, 7);
        let off = one_f_one_b_overlap(3.0, 1.0, 2.0, 5.0, 4, 7, false);
        assert_eq!(off.slot, legacy.slot);
        assert_eq!(off.step, legacy.step);
        assert_eq!(off.bubble_frac, legacy.bubble_frac);
    }

    #[test]
    fn compute_bound_slot_hides_all_comm() {
        // comm smaller than compute in both directions: the slot is just
        // the compute time — comm vanishes from the critical path
        let t = one_f_one_b_overlap(3.0, 1.0, 6.0, 2.0, 1, 4, true);
        assert_eq!(t.slot, 3.0 + 6.0);
        assert_eq!(t.step, 9.0 * 4.0);
    }

    #[test]
    fn comm_bound_slot_pays_comm() {
        // comm dominates: hiding saturates at max(comm, compute) = comm
        let t = one_f_one_b_overlap(1.0, 4.0, 2.0, 8.0, 1, 4, true);
        assert_eq!(t.slot, 4.0 + 8.0);
    }

    #[test]
    fn overlap_bounded_between_half_and_full_serial() {
        // max(a,b) ∈ [ (a+b)/2, a+b ]: overlap never worse than serial,
        // never better than halving it
        for (cf, mf, cb, mb) in [(3.0, 1.0, 2.0, 5.0), (1.0, 1.0, 4.0, 4.0), (0.5, 6.0, 6.0, 0.5)]
        {
            let serial = one_f_one_b_overlap(cf, mf, cb, mb, 4, 8, false);
            let over = one_f_one_b_overlap(cf, mf, cb, mb, 4, 8, true);
            assert!(over.step <= serial.step + 1e-12);
            assert!(over.step >= serial.step / 2.0 - 1e-12);
        }
    }
}
