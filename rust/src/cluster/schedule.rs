//! 1F1B pipeline schedule timing (§4.2: experiments use Megatron's
//! 1F1B-overlap-compatible configuration, without comm overlap).
//!
//! Classic 1F1B: steady state interleaves one forward and one backward per
//! stage; total step time ≈ (n_micro + pp − 1) slots where a slot is the
//! per-stage fwd+bwd time of one microbatch, plus the warmup/drain bubble.

/// Pipeline timing summary (seconds).
#[derive(Clone, Copy, Debug)]
pub struct PipelineTime {
    /// fwd+bwd time of one microbatch on one stage.
    pub slot: f64,
    /// total step wallclock.
    pub step: f64,
    /// bubble fraction (idle / total).
    pub bubble_frac: f64,
}

/// Compute 1F1B step time given per-stage per-microbatch fwd and bwd times.
pub fn one_f_one_b(fwd: f64, bwd: f64, pp: usize, n_micro: usize) -> PipelineTime {
    assert!(pp >= 1 && n_micro >= 1);
    let slot = fwd + bwd;
    // steady-state occupancy: n_micro slots, plus (pp-1) warmup+drain
    let step = slot * (n_micro as f64 + (pp as f64 - 1.0));
    let busy = slot * n_micro as f64;
    PipelineTime { slot, step, bubble_frac: 1.0 - busy / step }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_pipeline_no_bubble() {
        let t = one_f_one_b(1.0, 2.0, 1, 16);
        assert_eq!(t.step, 48.0);
        assert_eq!(t.bubble_frac, 0.0);
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        let few = one_f_one_b(1.0, 2.0, 8, 8);
        let many = one_f_one_b(1.0, 2.0, 8, 64);
        assert!(many.bubble_frac < few.bubble_frac);
        assert!((few.bubble_frac - 7.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn deeper_pipeline_larger_bubble() {
        let shallow = one_f_one_b(1.0, 2.0, 8, 64);
        let deep = one_f_one_b(1.0, 2.0, 32, 64);
        assert!(deep.bubble_frac > shallow.bubble_frac);
    }
}
