//! DeepEP-style all-to-all cost model with explicit Q/DQ accounting —
//! the Table 1 generator.
//!
//! The paper's two findings this model reproduces structurally:
//! 1. FP8 halves payload but ships a scale sidecar in extra buffers with
//!    extra synchronizations, capping the comm speedup near 1.6–1.7×;
//! 2. quantize/dequantize kernels cost a near-constant ~0.08–0.13 ms
//!    regardless of payload (launch + sync dominated at these sizes), so
//!    for small messages they erase the FP8 gain (ALL speedup → 1.0×).

use crate::cluster::topology::Layout;

/// Wire precision of the all-to-all payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wire {
    /// 2-byte payload per element.
    Bf16,
    /// 1-byte payload plus scale sidecar.
    Fp8,
}

/// One Table 1 measurement row.
#[derive(Clone, Copy, Debug)]
pub struct CommRow {
    /// Token rows.
    pub m: usize,
    /// Feature columns.
    pub n: usize,
    /// EP group size.
    pub ep: usize,
    /// BF16-wire all-to-all latency (ms).
    pub bf16_ms: f64,
    /// Pre-wire quantize cost (ms).
    pub quant_ms: f64,
    /// Post-wire dequantize cost (ms).
    pub dequant_ms: f64,
    /// FP8-wire all-to-all latency alone (ms).
    pub fp8_comm_ms: f64,
    /// FP8 end to end: quantize + wire + dequantize (ms).
    pub fp8_all_ms: f64,
    /// BF16 over FP8, wire only.
    pub speedup_comm: f64,
    /// BF16 over FP8, end to end.
    pub speedup_all: f64,
}

/// All-to-all latency for an `[m, n]` token buffer at the given wire
/// precision (seconds).
pub fn a2a_latency(l: &Layout, m: usize, n: usize, wire: Wire) -> f64 {
    let payload = match wire {
        Wire::Bf16 => (m * n * 2) as f64,
        // FP8 payload + f32 scale per 128 elements
        Wire::Fp8 => (m * n) as f64 * (1.0 + 4.0 / 128.0),
    };
    // FP8 ships payload and scales as separate buffers with their own
    // synchronization round: double the α term (§3.3.2's "doubles the
    // number of data buffers and synchronizations").
    let alpha = match wire {
        Wire::Bf16 => l.a2a_alpha(),
        Wire::Fp8 => 2.0 * l.a2a_alpha(),
    };
    alpha + payload / l.a2a_bandwidth()
}

/// Quantization kernel latency for an `[m, n]` buffer (seconds): a fixed
/// launch/sync floor plus a (small) memory-bound term — near-constant at
/// Table 1 sizes, exactly the paper's observation.
pub fn quant_latency(l: &Layout, m: usize, n: usize) -> f64 {
    // each rank quantizes its LOCAL shard of the buffer (m/ep rows):
    // launch+sync dominates, hence the near-constant cost in Table 1
    let bytes = ((m / l.ep) * n * 3) as f64; // read bf16 + write fp8(+scales)
    18.0 * l.hw.launch_overhead + bytes / l.hw.hbm_bw
}

/// Dequantization kernel latency (symmetric).
pub fn dequant_latency(l: &Layout, m: usize, n: usize) -> f64 {
    let bytes = ((m / l.ep) * n * 3) as f64;
    17.0 * l.hw.launch_overhead + bytes / l.hw.hbm_bw
}

/// Produce one Table 1 row for `(m, n, ep)`.
pub fn table1_row(m: usize, n: usize, ep: usize) -> CommRow {
    let l = Layout::new(ep, 256 / ep);
    let bf16 = a2a_latency(&l, m, n, Wire::Bf16);
    let q = quant_latency(&l, m, n);
    let d = dequant_latency(&l, m, n);
    let fp8 = a2a_latency(&l, m, n, Wire::Fp8);
    let all = q + fp8 + d;
    CommRow {
        m,
        n,
        ep,
        bf16_ms: bf16 * 1e3,
        quant_ms: q * 1e3,
        dequant_ms: d * 1e3,
        fp8_comm_ms: fp8 * 1e3,
        fp8_all_ms: all * 1e3,
        speedup_comm: bf16 / fp8,
        speedup_all: bf16 / all,
    }
}

/// The paper's nine Table 1 configurations.
pub const TABLE1_CONFIGS: [(usize, usize, usize); 9] = [
    (24576, 2048, 8),
    (24576, 5120, 8),
    (32768, 7168, 8),
    (24576, 2048, 16),
    (24576, 5120, 16),
    (32768, 7168, 16),
    (24576, 2048, 32),
    (24576, 5120, 32),
    (32768, 7168, 32),
];

/// Paper-reported Table 1 values `(bf16, q, dq, comm, all, s_comm, s_all)`
/// for side-by-side reporting in the bench.
pub const TABLE1_PAPER: [(f64, f64, f64, f64, f64, f64, f64); 9] = [
    (0.537, 0.127, 0.084, 0.325, 0.535, 1.65, 1.00),
    (0.785, 0.087, 0.089, 0.526, 0.703, 1.49, 1.12),
    (1.276, 0.086, 0.089, 0.905, 1.080, 1.41, 1.18),
    (1.224, 0.091, 0.083, 1.176, 1.350, 1.04, 0.91),
    (2.213, 0.082, 0.082, 1.400, 1.564, 1.58, 1.42),
    (2.934, 0.084, 0.092, 1.847, 2.023, 1.59, 1.45),
    (3.005, 0.094, 0.083, 2.740, 2.918, 1.10, 1.03),
    (5.003, 0.082, 0.081, 2.868, 3.031, 1.74, 1.65),
    (7.327, 0.082, 0.082, 4.319, 4.483, 1.70, 1.63),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_comm_always_faster_than_bf16() {
        for &(m, n, ep) in &TABLE1_CONFIGS {
            let r = table1_row(m, n, ep);
            assert!(r.speedup_comm > 1.0, "({m},{n},{ep}): {:?}", r.speedup_comm);
            assert!(r.speedup_comm < 2.0, "payload halving caps the gain");
        }
    }

    #[test]
    fn qdq_erodes_the_gain() {
        for &(m, n, ep) in &TABLE1_CONFIGS {
            let r = table1_row(m, n, ep);
            assert!(r.speedup_all < r.speedup_comm, "({m},{n},{ep})");
        }
    }

    #[test]
    fn qdq_is_near_constant_while_comm_scales() {
        let small = table1_row(24576, 2048, 16);
        let large = table1_row(32768, 7168, 16);
        // comm grows with the payload (4.7× more bytes; α damps the ratio —
        // the paper's own EP16 column grows only 1.6×)...
        assert!(large.fp8_comm_ms / small.fp8_comm_ms > 2.0);
        // ...while q/dq grows far slower (launch-dominated)
        assert!(large.quant_ms / small.quant_ms < 2.0);
    }

    #[test]
    fn erosion_worst_for_small_messages() {
        let small = table1_row(24576, 2048, 8);
        let large = table1_row(32768, 7168, 8);
        let erosion_small = small.speedup_comm - small.speedup_all;
        let erosion_large = large.speedup_comm - large.speedup_all;
        assert!(
            erosion_small > erosion_large,
            "small {erosion_small} vs large {erosion_large}"
        );
    }

    #[test]
    fn comm_grows_with_ep() {
        for n in [2048usize, 5120] {
            let t8 = table1_row(24576, n, 8).bf16_ms;
            let t16 = table1_row(24576, n, 16).bf16_ms;
            let t32 = table1_row(24576, n, 32).bf16_ms;
            assert!(t8 < t16 && t16 < t32, "n={n}: {t8} {t16} {t32}");
        }
    }

    #[test]
    fn same_order_as_paper() {
        // within ~3× of the paper's absolute numbers everywhere (shape
        // fidelity target; exact ms are testbed-specific)
        for (i, &(m, n, ep)) in TABLE1_CONFIGS.iter().enumerate() {
            let r = table1_row(m, n, ep);
            let p = TABLE1_PAPER[i];
            let ratio = r.bf16_ms / p.0;
            assert!((0.33..3.0).contains(&ratio), "({m},{n},{ep}) bf16 {} vs paper {}", r.bf16_ms, p.0);
        }
    }
}
