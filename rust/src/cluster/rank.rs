//! Rank-group runtime — simulated expert-parallel ranks as disjoint
//! worker sub-pools, plus the in-memory wire between them.
//!
//! The cluster simulator ([`crate::cluster::sim`]) *costs* EP dispatch
//! analytically; this module provides the substrate that *executes* it:
//!
//! * [`RankGroup`] — R simulated ranks, each backed by a disjoint worker
//!   share of the process budget ([`crate::exec::WorkerGroup`]). A phase
//!   runs one body per rank concurrently and reports per-rank and
//!   wall-clock seconds, which is what turns the simulator's claims into
//!   measurements.
//! * [`WireBuf`] / [`all_to_all`] — the in-memory all-to-all. FP8
//!   messages ship the u8 payload and the UE8M0 scale sidecar as two
//!   *separate* buffers, mirroring [`crate::cluster::comm`]'s two-buffer
//!   cost model (§3.3.2: FP8 "doubles the number of data buffers and
//!   synchronizations"); BF16-wire recipes ship one dense buffer.
//!
//! The UE8M0 sidecar is bit-faithful: po2 tile scales satisfy
//! `scale == 2^sexp` ([`crate::fp8::tile::tile_scale`]), so shipping the
//! biased exponent byte and re-deriving the scale with
//! [`crate::fp8::ue8m0::decode`] reproduces the exact f32 scale — the
//! executed dispatch is bitwise equal to a local `permute_pad_fp8`.

use crate::exec::WorkerGroup;
use std::time::Instant;

/// What one rank body knows about itself.
#[derive(Clone, Copy, Debug)]
pub struct RankCtx {
    /// This rank's index.
    pub rank: usize,
    /// Total rank count.
    pub n_ranks: usize,
    /// Worker budget for kernels called inside this rank's body
    /// (pass to the `*_with_threads` kernel forms).
    pub workers: usize,
}

/// R simulated ranks over disjoint worker sub-pools.
#[derive(Clone, Debug)]
pub struct RankGroup {
    group: WorkerGroup,
}

/// Result of one barrier-synchronized phase across all ranks.
pub struct Phase<R> {
    /// Per-rank results, in rank order.
    pub results: Vec<R>,
    /// Per-rank body duration (seconds).
    pub rank_s: Vec<f64>,
    /// Wall-clock duration of the whole phase (max over ranks plus
    /// spawn/join overhead) — the number a real synchronized collective
    /// would observe.
    pub wall_s: f64,
}

impl RankGroup {
    /// `n_ranks` simulated ranks sharing `total_workers` (0 = resolve via
    /// [`crate::exec::threads`]). Every rank gets at least one worker.
    pub fn new(n_ranks: usize, total_workers: usize) -> RankGroup {
        let total = if total_workers == 0 { crate::exec::threads() } else { total_workers };
        RankGroup { group: WorkerGroup::new(n_ranks, total) }
    }

    /// Number of simulated ranks.
    pub fn n_ranks(&self) -> usize {
        self.group.len()
    }

    /// Worker budget of one rank.
    pub fn workers(&self, rank: usize) -> usize {
        self.group.budget(rank)
    }

    /// Run `f` once per rank, concurrently (rank 0 on the calling
    /// thread), with a barrier at the end — the executed analogue of one
    /// bulk-synchronous pipeline stage.
    pub fn run_phase<R, F>(&self, f: F) -> Phase<R>
    where
        R: Send,
        F: Fn(&RankCtx) -> R + Sync,
    {
        let n_ranks = self.group.len();
        let t0 = Instant::now();
        let timed: Vec<(R, f64)> = self.group.run(|rank, workers| {
            let ctx = RankCtx { rank, n_ranks, workers };
            let ts = Instant::now();
            let out = f(&ctx);
            (out, ts.elapsed().as_secs_f64())
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let (results, rank_s) = timed.into_iter().unzip();
        Phase { results, rank_s, wall_s }
    }
}

/// One directional message on the in-memory wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireBuf {
    /// BF16-wire recipes: one dense buffer (f32 in memory, accounted at
    /// 2 B/element — the BF16 stand-in used throughout the repo).
    Dense(Vec<f32>),
    /// FP8 wire: u8 codes and the UE8M0 scale sidecar as two separate
    /// buffers (the two-buffer model of `cluster/comm.rs`).
    Fp8 { codes: Vec<u8>, sidecar: Vec<u8> },
}

impl WireBuf {
    /// Payload bytes on the wire (excluding any sidecar).
    pub fn payload_bytes(&self) -> usize {
        match self {
            WireBuf::Dense(v) => v.len() * 2,
            WireBuf::Fp8 { codes, .. } => codes.len(),
        }
    }

    /// Sidecar bytes on the wire (UE8M0: 1 B per 1×128 tile).
    pub fn sidecar_bytes(&self) -> usize {
        match self {
            WireBuf::Dense(_) => 0,
            WireBuf::Fp8 { sidecar, .. } => sidecar.len(),
        }
    }

    /// Total bytes shipped.
    pub fn wire_bytes(&self) -> usize {
        self.payload_bytes() + self.sidecar_bytes()
    }

    /// Number of separate buffers (= synchronization rounds in the comm
    /// model: FP8 pays two, BF16 one).
    pub fn n_buffers(&self) -> usize {
        match self {
            WireBuf::Dense(_) => 1,
            WireBuf::Fp8 { .. } => 2,
        }
    }
}

/// The in-memory all-to-all: `mailbox[src][dst]` → `inbox[dst][src]`.
///
/// Pure ownership transposition — the wire itself is free in shared
/// memory; what the executed dispatch *measures* is the pack/assemble
/// memory traffic around it, which is exactly the part the Table 1 model
/// attributes to the payload term.
pub fn all_to_all<T>(mailbox: Vec<Vec<T>>) -> Vec<Vec<T>> {
    let r = mailbox.len();
    let mut inbox: Vec<Vec<T>> = (0..r).map(|_| Vec::with_capacity(r)).collect();
    for row in mailbox {
        assert_eq!(row.len(), r, "all_to_all mailbox must be square (R×R)");
        for (dst, buf) in row.into_iter().enumerate() {
            inbox[dst].push(buf);
        }
    }
    inbox
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::ue8m0;

    #[test]
    fn phase_runs_every_rank_with_disjoint_budgets() {
        let g = RankGroup::new(4, 8);
        assert_eq!(g.n_ranks(), 4);
        let total: usize = (0..4).map(|r| g.workers(r)).sum();
        assert_eq!(total, 8);
        let ph = g.run_phase(|ctx| (ctx.rank, ctx.workers, ctx.n_ranks));
        assert_eq!(ph.results.len(), 4);
        assert_eq!(ph.rank_s.len(), 4);
        assert!(ph.wall_s >= 0.0);
        for (i, &(rank, workers, n)) in ph.results.iter().enumerate() {
            assert_eq!(rank, i);
            assert_eq!(workers, g.workers(i));
            assert_eq!(n, 4);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        // mailbox[src][dst] = (src, dst)
        let mailbox: Vec<Vec<(usize, usize)>> =
            (0..3).map(|s| (0..3).map(|d| (s, d)).collect()).collect();
        let inbox = all_to_all(mailbox);
        for (d, row) in inbox.iter().enumerate() {
            for (s, &(src, dst)) in row.iter().enumerate() {
                assert_eq!((src, dst), (s, d));
            }
        }
    }

    #[test]
    fn wire_accounting() {
        let dense = WireBuf::Dense(vec![0.0; 10]);
        assert_eq!(dense.wire_bytes(), 20);
        assert_eq!(dense.n_buffers(), 1);
        let fp8 = WireBuf::Fp8 { codes: vec![0; 256], sidecar: vec![127; 2] };
        assert_eq!(fp8.payload_bytes(), 256);
        assert_eq!(fp8.sidecar_bytes(), 2);
        assert_eq!(fp8.wire_bytes(), 258);
        assert_eq!(fp8.n_buffers(), 2);
    }

    #[test]
    fn ue8m0_sidecar_roundtrips_po2_scales_bitwise() {
        // the wire contract: scale == 2^sexp survives the sidecar byte
        for e in -40i32..40 {
            let b = ue8m0::from_exponent(e);
            assert_eq!(ue8m0::exponent(b), e);
            assert_eq!(ue8m0::decode(b).to_bits(), (e as f32).exp2().to_bits());
        }
    }
}
