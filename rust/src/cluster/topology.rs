//! Cluster topology: nodes × GPUs, EP/PP process groups, link bandwidths.
//!
//! Mirrors the paper's testbed: 32 nodes × 8 H100-class GPUs (80 GB),
//! NVLink intra-node, RDMA inter-node, EP×PP = 256.

/// Hardware parameters of the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct Hardware {
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// Node count.
    pub n_nodes: usize,
    /// HBM capacity per GPU (bytes).
    pub hbm_bytes: u64,
    /// Dense BF16 peak (FLOP/s) per GPU.
    pub bf16_flops: f64,
    /// FP8 peak = 2× BF16 on Hopper tensor cores.
    pub fp8_flops: f64,
    /// HBM bandwidth (B/s).
    pub hbm_bw: f64,
    /// NVLink per-GPU bandwidth (B/s), intra-node all-to-all.
    pub nvlink_bw: f64,
    /// RDMA per-GPU bandwidth (B/s), inter-node all-to-all.
    pub rdma_bw: f64,
    /// Kernel launch + sync overhead (s).
    pub launch_overhead: f64,
    /// All-to-all base latency intra-node (s).
    pub a2a_alpha_intra: f64,
    /// All-to-all base latency inter-node (s).
    pub a2a_alpha_inter: f64,
    /// Achievable fraction of peak for big GEMMs.
    pub gemm_efficiency: f64,
}

/// H100-class defaults (survive calibration: see EXPERIMENTS.md Table 1/2).
pub const H100_CLUSTER: Hardware = Hardware {
    gpus_per_node: 8,
    n_nodes: 32,
    hbm_bytes: 80 * (1 << 30),
    bf16_flops: 990e12,
    fp8_flops: 1980e12,
    hbm_bw: 3.35e12,
    nvlink_bw: 300e9,
    rdma_bw: 45e9,
    launch_overhead: 4e-6,
    a2a_alpha_intra: 25e-6,
    a2a_alpha_inter: 180e-6,
    gemm_efficiency: 0.55,
};

/// An EP×PP parallel layout over the cluster.
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    /// Expert-parallel group size.
    pub ep: usize,
    /// Pipeline-parallel stages.
    pub pp: usize,
    /// Hardware parameters.
    pub hw: Hardware,
}

impl Layout {
    /// Layout over the default H100-class cluster.
    pub fn new(ep: usize, pp: usize) -> Layout {
        Layout { ep, pp, hw: H100_CLUSTER }
    }

    /// Total GPUs used.
    pub fn n_gpus(&self) -> usize {
        self.ep * self.pp
    }

    /// Fraction of an EP group's peers reachable intra-node.
    pub fn intra_fraction(&self) -> f64 {
        if self.ep <= self.hw.gpus_per_node {
            1.0
        } else {
            self.hw.gpus_per_node as f64 / self.ep as f64
        }
    }

    /// Effective per-GPU all-to-all bandwidth for this EP degree: the
    /// blend of NVLink (intra) and RDMA (inter) paths, degraded mildly by
    /// group size (incast/contention).
    pub fn a2a_bandwidth(&self) -> f64 {
        let fi = self.intra_fraction();
        let blend = fi * self.hw.nvlink_bw + (1.0 - fi) * self.hw.rdma_bw;
        // contention factor: larger groups lose efficiency
        let groups = (self.ep as f64 / self.hw.gpus_per_node as f64).max(1.0);
        blend / groups.powf(0.35)
    }

    /// Base all-to-all latency for this EP degree.
    pub fn a2a_alpha(&self) -> f64 {
        if self.ep <= self.hw.gpus_per_node {
            self.hw.a2a_alpha_intra
        } else {
            self.hw.a2a_alpha_inter
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_layouts() {
        for (ep, pp) in [(8, 32), (16, 16), (32, 8)] {
            let l = Layout::new(ep, pp);
            assert_eq!(l.n_gpus(), 256);
        }
    }

    #[test]
    fn bandwidth_decreases_with_ep() {
        let b8 = Layout::new(8, 32).a2a_bandwidth();
        let b16 = Layout::new(16, 16).a2a_bandwidth();
        let b32 = Layout::new(32, 8).a2a_bandwidth();
        assert!(b8 > b16 && b16 > b32, "{b8} {b16} {b32}");
    }

    #[test]
    fn intra_node_is_full_nvlink() {
        let l = Layout::new(8, 32);
        assert_eq!(l.intra_fraction(), 1.0);
        assert_eq!(l.a2a_alpha(), H100_CLUSTER.a2a_alpha_intra);
    }

    #[test]
    fn fp8_is_double_bf16_peak() {
        assert_eq!(H100_CLUSTER.fp8_flops, 2.0 * H100_CLUSTER.bf16_flops);
    }
}
