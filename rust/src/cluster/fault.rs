//! Deterministic fault injection and recovery for the EP runtime.
//!
//! Long FP8 runs at the paper's 671B scale live with rank loss,
//! stragglers and wire corruption as the norm, not the exception. This
//! module makes every such failure **replayable from a seed**: a
//! [`FaultPlan`] schedules faults at (tick, src, dst) coordinates on the
//! wire, and the delivery path recovers through checksummed
//! retransmission with deterministic backoff on a virtual clock —
//! so a chaos run is as reproducible as any other experiment in this
//! repo.
//!
//! **Wire integrity.** Every all-to-all message is sealed with one CRC32
//! per buffer — the FP8 codes and the UE8M0 scale sidecar get *separate*
//! seals ([`WireSums`]). The split matters: a flipped payload byte
//! perturbs one FP8 element, but a flipped sidecar byte rescales a whole
//! 1×128 tile by a silent power of two (`scale == 2^sexp`) — the worst
//! double-quantization-adjacent corruption, invisible to any range
//! check. CRC32 detects 100% of single-bit errors in either buffer
//! (`tests/prop_fault.rs` proves it exhaustively), so a detected
//! mismatch triggers bounded retransmission and the recovered delivery
//! is **bitwise identical** to the uncorrupted one — fault injection
//! never perturbs numerics, only the recovery counters and the virtual
//! clock. The repo-wide bit-identity contract therefore extends to any
//! seeded fault plan.
//!
//! **Recovery ladder.** Detected corruption, or a dropped message
//! (virtual-clock timeout), is retried with exponential backoff
//! ([`BACKOFF_BASE_NS`] ` << attempt`). After [`MAX_A2A_RETRIES`]
//! retransmissions the receiver escalates to **rank failover**: the
//! source rank is marked failed (consumed by the degraded serving path
//! in `serve/engine.rs`) and the message is re-sourced from the
//! surviving replica — in this in-memory simulation, the pristine
//! buffer. Counters: [`Counter::WireChecksumFail`],
//! [`Counter::A2aRetries`], [`Counter::Failovers`], mirrored in
//! [`FaultStats`] for recorder-free assertions.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::{self, Counter};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::rank::WireBuf;

/// Retransmissions allowed before a delivery escalates to rank failover.
pub const MAX_A2A_RETRIES: u32 = 3;

/// Backoff after the n-th failed reception: `BACKOFF_BASE_NS << n`
/// virtual nanoseconds (deterministic exponential backoff).
pub const BACKOFF_BASE_NS: u64 = 1 << 20;

/// Virtual-clock timeout charged when a dropped message is detected.
pub const TIMEOUT_NS: u64 = 1 << 22;

/// Virtual-clock cost of a rank failover (replica re-source).
pub const FAILOVER_NS: u64 = 1 << 24;

/// Wildcard destination: the fault hits the message to every receiver.
pub const ANY_DST: usize = usize::MAX;

// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc_update(mut c: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

/// CRC32 (IEEE) of `bytes`. Detects every single-bit and single-byte
/// error, which is exactly the wire-corruption class injected here.
pub fn checksum(bytes: &[u8]) -> u32 {
    !crc_update(!0u32, bytes)
}

/// CRC32 over an f32 slice's little-endian byte image (the dense wire).
pub fn checksum_f32(vals: &[f32]) -> u32 {
    let mut c = !0u32;
    for v in vals {
        c = crc_update(c, &v.to_le_bytes());
    }
    !c
}

/// The two per-buffer seals of one wire message. Codes and UE8M0
/// sidecar are sealed **separately**: the sidecar is ~1/128 of the
/// payload, so folding it into one sum would let a payload-sized burst
/// mask a sidecar flip — and a sidecar flip is the silent `2^±k` scale
/// error the paper's recipe exists to avoid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireSums {
    /// CRC32 of the payload buffer (FP8 codes, or the dense f32 image).
    pub payload: u32,
    /// CRC32 of the UE8M0 sidecar buffer (0 for dense: no sidecar).
    pub sidecar: u32,
}

impl WireSums {
    /// Seal both buffers of `buf` (the sender side of the wire contract).
    pub fn seal(buf: &WireBuf) -> WireSums {
        match buf {
            WireBuf::Dense(v) => WireSums { payload: checksum_f32(v), sidecar: 0 },
            WireBuf::Fp8 { codes, sidecar } => {
                WireSums { payload: checksum(codes), sidecar: checksum(sidecar) }
            }
        }
    }

    /// Receiver-side check: true iff both buffers re-seal to `self`.
    pub fn verify(&self, buf: &WireBuf) -> bool {
        *self == WireSums::seal(buf)
    }
}

/// What a scheduled fault does to its matching delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip bit `bit & 7` of payload byte `offset % len` (FP8 codes, or
    /// the f32 byte image on a dense wire).
    FlipPayloadBit {
        /// Byte offset, reduced mod the buffer length at injection time.
        offset: usize,
        /// Bit index 0..8 within the byte.
        bit: u8,
    },
    /// Flip bit `bit & 7` of UE8M0 sidecar byte `offset % len` — a
    /// silent `2^±k` tile-scale error if it went undetected.
    FlipSidecarBit {
        /// Byte offset, reduced mod the sidecar length at injection time.
        offset: usize,
        /// Bit index 0..8 within the byte.
        bit: u8,
    },
    /// The message never arrives; the receiver times out and requests
    /// retransmission.
    DropMessage,
    /// Straggler: the delivery lands late by `delay_ns` on the virtual
    /// clock (no retry, no corruption).
    Straggler {
        /// Added virtual latency in nanoseconds.
        delay_ns: u64,
    },
    /// The source rank crashes at this tick (degraded-serving /
    /// checkpoint-resume driver; on the EP wire it escalates straight to
    /// failover).
    CrashRank,
}

/// One scheduled fault: `kind` hits deliveries at `tick` from `src` to
/// `dst` (or every destination when `dst == ANY_DST`), corrupting the
/// first `attempts` consecutive receptions of each matching delivery.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    /// Virtual tick coordinate (see [`wire_tick`] for the EP wire; the
    /// serve tick index for serving; the train step for checkpointing).
    pub tick: u64,
    /// Source rank of the afflicted message.
    pub src: usize,
    /// Destination rank, or [`ANY_DST`].
    pub dst: usize,
    /// What happens to the message.
    pub kind: FaultKind,
    /// Consecutive corrupted receptions before the fault clears
    /// (`> MAX_A2A_RETRIES` forces failover).
    pub attempts: u32,
}

/// Recovery totals, mirrored from the `obs` counters so tests and the
/// chaos driver can assert them without installing a recorder.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Buffers whose CRC32 failed on receive.
    pub checksum_fails: u64,
    /// Bounded retransmissions issued.
    pub retries: u64,
    /// Rank failovers after retry exhaustion (incl. injected crashes).
    pub failovers: u64,
    /// Virtual nanoseconds spent in backoff/timeout/failover.
    pub clock_ns: u64,
}

impl FaultStats {
    /// JSON object for the `runs/chaos_*.json` schema.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("wire_checksum_fail", self.checksum_fails)
            .set("a2a_retries", self.retries)
            .set("failovers", self.failovers)
            .set("recovery_clock_ns", self.clock_ns)
    }
}

/// A seeded, replayable fault schedule plus the shared recovery state
/// (virtual clock, failed-rank set, counters). Threading: `deliver` may
/// run concurrently from overlap-pipeline lanes; all shared state is
/// atomic and every update commutes, so counter totals and the final
/// clock are schedule-independent — deterministic under any thread
/// budget, which is what lets property tests assert exact totals.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    seed: u64,
    /// Bitmask of failed ranks (rank r fails ⇒ bit r set; ranks < 64).
    failed: AtomicU64,
    clock_ns: AtomicU64,
    checksum_fails: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
}

impl FaultPlan {
    /// The empty plan: `deliver` is a no-op (the fault-free fast path —
    /// no checksums are computed, so the default runtime is untouched).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A plan with an explicit fault list (property tests, the chaos
    /// driver's targeted scenarios).
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { faults, ..FaultPlan::default() }
    }

    /// A seeded random injection matrix: `n_faults` faults over
    /// `n_ranks` sources and `n_ticks` ticks, kinds weighted toward the
    /// corruption classes the checksum exists for. Same seed ⇒ same
    /// plan ⇒ same recovery counters: chaos runs are replayable.
    pub fn seeded(seed: u64, n_ranks: usize, n_ticks: u64, n_faults: usize) -> FaultPlan {
        let mut rng = Rng::seed_from(seed ^ 0xFA17);
        let faults = (0..n_faults)
            .map(|_| {
                let kind = match rng.below(8) {
                    0 | 1 => FaultKind::FlipPayloadBit {
                        offset: rng.next_u64() as usize,
                        bit: rng.below(8) as u8,
                    },
                    2 | 3 => FaultKind::FlipSidecarBit {
                        offset: rng.next_u64() as usize,
                        bit: rng.below(8) as u8,
                    },
                    4 => FaultKind::DropMessage,
                    5 | 6 => FaultKind::Straggler {
                        delay_ns: BACKOFF_BASE_NS + rng.below(4 * BACKOFF_BASE_NS as usize) as u64,
                    },
                    _ => FaultKind::CrashRank,
                };
                Fault {
                    tick: rng.below(n_ticks.max(1) as usize) as u64,
                    src: rng.below(n_ranks),
                    dst: if rng.below(2) == 0 { ANY_DST } else { rng.below(n_ranks) },
                    kind,
                    attempts: 1 + rng.below(MAX_A2A_RETRIES as usize + 2) as u32,
                }
            })
            .collect();
        FaultPlan { faults, seed, ..FaultPlan::default() }
    }

    /// The seed this plan replays from (0 for explicit plans).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True when any fault is scheduled (the delivery path verifies
    /// checksums only on armed plans; unarmed delivery is a no-op).
    pub fn armed(&self) -> bool {
        !self.faults.is_empty()
    }

    /// The scheduled faults.
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// True when `rank` has failed (crash fault or retry-exhaustion
    /// failover).
    pub fn is_failed(&self, rank: usize) -> bool {
        rank < 64 && self.failed.load(Ordering::Relaxed) & (1u64 << rank) != 0
    }

    /// Recovery totals so far.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            checksum_fails: self.checksum_fails.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            clock_ns: self.clock_ns.load(Ordering::Relaxed),
        }
    }

    /// Mark newly crashed sources at serve tick `tick` (consuming every
    /// `CrashRank` fault scheduled there) and return them. Idempotent
    /// per rank: an already-failed rank is not returned again.
    pub fn crashed_at(&self, tick: u64) -> Vec<usize> {
        let mut out = Vec::new();
        for f in &self.faults {
            if f.tick == tick && f.kind == FaultKind::CrashRank && !self.is_failed(f.src) {
                self.fail_over(f.src);
                out.push(f.src);
            }
        }
        out
    }

    /// Receiver-side delivery of one wire message at `tick` from `src`
    /// to `dst`. On an armed plan the message is sealed ([`WireSums`])
    /// and every matching fault is injected: corrupted receptions are
    /// detected by the per-buffer CRC32 and retried with deterministic
    /// backoff; exhausted retries escalate to failover. The delivered
    /// bytes are always the pristine `buf` — recovery is bitwise by
    /// construction, so callers keep using their original buffer.
    pub fn deliver(&self, tick: u64, src: usize, dst: usize, buf: &WireBuf) {
        if self.faults.is_empty() {
            return;
        }
        let mut seal: Option<WireSums> = None;
        for f in &self.faults {
            if f.tick != tick || f.src != src || (f.dst != ANY_DST && f.dst != dst) {
                continue;
            }
            match f.kind {
                FaultKind::Straggler { delay_ns } => {
                    self.clock_ns.fetch_add(delay_ns, Ordering::Relaxed);
                }
                FaultKind::CrashRank => self.fail_over(src),
                _ => {
                    let s = *seal.get_or_insert_with(|| WireSums::seal(buf));
                    self.recover(f, buf, s);
                }
            }
        }
    }

    /// Serve-level delivery: inject every non-crash fault scheduled at
    /// `tick` into the tick's wire image, whatever its (src, dst). The
    /// serving tick is one logical collective, so tick-granular matching
    /// is the natural coordinate there.
    pub fn deliver_tick(&self, tick: u64, buf: &WireBuf) {
        if self.faults.is_empty() {
            return;
        }
        let mut seal: Option<WireSums> = None;
        for f in &self.faults {
            if f.tick != tick {
                continue;
            }
            match f.kind {
                FaultKind::Straggler { delay_ns } => {
                    self.clock_ns.fetch_add(delay_ns, Ordering::Relaxed);
                }
                FaultKind::CrashRank => {} // handled by `crashed_at`
                _ => {
                    let s = *seal.get_or_insert_with(|| WireSums::seal(buf));
                    self.recover(f, buf, s);
                }
            }
        }
    }

    /// The bounded retry loop for one delivery afflicted by `f`.
    /// Reception `n` is corrupted iff `n < f.attempts`; a failed
    /// reception after [`MAX_A2A_RETRIES`] retransmissions escalates to
    /// failover. Counter totals are a pure function of the fault, so
    /// they are identical across serial/overlap schedules.
    fn recover(&self, f: &Fault, buf: &WireBuf, seal: WireSums) {
        for attempt in 0u32.. {
            let ok = if attempt >= f.attempts {
                true // the fault has cleared: pristine retransmission
            } else {
                match f.kind {
                    FaultKind::DropMessage => {
                        // nothing arrived: detected by timeout, nothing
                        // to checksum
                        self.clock_ns.fetch_add(TIMEOUT_NS, Ordering::Relaxed);
                        false
                    }
                    _ => match corrupted(buf, &f.kind) {
                        Some(bad) => {
                            let detected = !seal.verify(&bad);
                            if detected {
                                self.checksum_fails.fetch_add(1, Ordering::Relaxed);
                                obs::count(Counter::WireChecksumFail, 1);
                            }
                            // An undetected corruption would be accepted
                            // here — CRC32 makes that unreachable for
                            // bit flips (prop_fault pins it), which is
                            // exactly why the check is load-bearing.
                            !detected
                        }
                        // fault targets a buffer this message doesn't
                        // carry (e.g. sidecar flip on a dense wire)
                        None => true,
                    },
                }
            };
            if ok {
                return;
            }
            if attempt >= MAX_A2A_RETRIES {
                self.fail_over(f.src);
                return;
            }
            self.retries.fetch_add(1, Ordering::Relaxed);
            obs::count(Counter::A2aRetries, 1);
            self.clock_ns.fetch_add(BACKOFF_BASE_NS << attempt, Ordering::Relaxed);
        }
    }

    fn fail_over(&self, rank: usize) {
        if rank < 64 {
            self.failed.fetch_or(1u64 << rank, Ordering::Relaxed);
        }
        self.failovers.fetch_add(1, Ordering::Relaxed);
        obs::count(Counter::Failovers, 1);
        self.clock_ns.fetch_add(FAILOVER_NS, Ordering::Relaxed);
    }
}

/// The EP wire's tick coordinate: one value per (top-k slot, chunk
/// round, direction), identical across the serialized and overlapped
/// schedules — so a fault plan replays to the same counters whatever
/// `--overlap`/`--chunks` say.
pub fn wire_tick(kk: usize, chunk: usize, backward: bool) -> u64 {
    ((backward as u64) << 48) | ((kk as u64) << 24) | chunk as u64
}

/// The corrupted image of `buf` under a flip fault, or `None` when the
/// fault targets a buffer the message doesn't carry (empty buffer, or a
/// sidecar flip on a dense wire).
fn corrupted(buf: &WireBuf, kind: &FaultKind) -> Option<WireBuf> {
    match (buf, kind) {
        (WireBuf::Fp8 { codes, sidecar }, FaultKind::FlipPayloadBit { offset, bit })
            if !codes.is_empty() =>
        {
            let mut c = codes.clone();
            let o = offset % c.len();
            c[o] ^= 1u8 << (bit & 7);
            Some(WireBuf::Fp8 { codes: c, sidecar: sidecar.clone() })
        }
        (WireBuf::Fp8 { codes, sidecar }, FaultKind::FlipSidecarBit { offset, bit })
            if !sidecar.is_empty() =>
        {
            let mut s = sidecar.clone();
            let o = offset % s.len();
            s[o] ^= 1u8 << (bit & 7);
            Some(WireBuf::Fp8 { codes: codes.clone(), sidecar: s })
        }
        (WireBuf::Dense(v), FaultKind::FlipPayloadBit { offset, bit }) if !v.is_empty() => {
            let mut d = v.clone();
            let byte = offset % (d.len() * 4);
            let bits = d[byte / 4].to_bits() ^ (1u32 << ((byte % 4) * 8 + (bit & 7) as usize));
            d[byte / 4] = f32::from_bits(bits);
            Some(WireBuf::Dense(d))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE test vector
        assert_eq!(checksum(b"123456789"), 0xCBF4_3926);
        assert_eq!(checksum(b""), 0);
    }

    #[test]
    fn seals_are_per_buffer() {
        let buf = WireBuf::Fp8 { codes: vec![1, 2, 3], sidecar: vec![127, 128] };
        let s = WireSums::seal(&buf);
        assert!(s.verify(&buf));
        let flipped = WireBuf::Fp8 { codes: vec![1, 2, 3], sidecar: vec![127, 129] };
        let f = WireSums::seal(&flipped);
        assert_eq!(f.payload, s.payload, "payload seal must not cover the sidecar");
        assert_ne!(f.sidecar, s.sidecar, "sidecar flip must change the sidecar seal");
        assert!(!s.verify(&flipped));
    }

    #[test]
    fn dense_seal_covers_f32_bits() {
        let buf = WireBuf::Dense(vec![1.0, -0.5, 3.25]);
        let s = WireSums::seal(&buf);
        assert_eq!(s.sidecar, 0);
        let mut v = vec![1.0f32, -0.5, 3.25];
        v[1] = f32::from_bits(v[1].to_bits() ^ 1);
        assert!(!s.verify(&WireBuf::Dense(v)));
    }

    #[test]
    fn transient_flip_recovers_with_counted_retries() {
        let plan = FaultPlan::new(vec![Fault {
            tick: 7,
            src: 1,
            dst: 0,
            kind: FaultKind::FlipSidecarBit { offset: 5, bit: 3 },
            attempts: 2,
        }]);
        let buf = WireBuf::Fp8 { codes: vec![9; 64], sidecar: vec![130; 4] };
        plan.deliver(7, 1, 0, &buf); // match
        plan.deliver(7, 0, 0, &buf); // wrong src: clean
        plan.deliver(8, 1, 0, &buf); // wrong tick: clean
        let st = plan.stats();
        assert_eq!(st.checksum_fails, 2);
        assert_eq!(st.retries, 2);
        assert_eq!(st.failovers, 0);
        assert_eq!(st.clock_ns, BACKOFF_BASE_NS + (BACKOFF_BASE_NS << 1));
        assert!(!plan.is_failed(1));
    }

    #[test]
    fn persistent_fault_escalates_to_failover() {
        let plan = FaultPlan::new(vec![Fault {
            tick: 0,
            src: 2,
            dst: ANY_DST,
            kind: FaultKind::FlipPayloadBit { offset: 0, bit: 0 },
            attempts: MAX_A2A_RETRIES + 5,
        }]);
        let buf = WireBuf::Fp8 { codes: vec![1; 8], sidecar: vec![127] };
        plan.deliver(0, 2, 3, &buf);
        let st = plan.stats();
        // receptions 0..=MAX all fail, then escalation
        assert_eq!(st.checksum_fails, MAX_A2A_RETRIES as u64 + 1);
        assert_eq!(st.retries, MAX_A2A_RETRIES as u64);
        assert_eq!(st.failovers, 1);
        assert!(plan.is_failed(2));
    }

    #[test]
    fn dropped_message_retries_without_checksum_fail() {
        let plan = FaultPlan::new(vec![Fault {
            tick: 3,
            src: 0,
            dst: 1,
            kind: FaultKind::DropMessage,
            attempts: 1,
        }]);
        plan.deliver(3, 0, 1, &WireBuf::Dense(vec![2.0; 4]));
        let st = plan.stats();
        assert_eq!(st.checksum_fails, 0);
        assert_eq!(st.retries, 1);
        assert_eq!(st.clock_ns, TIMEOUT_NS + BACKOFF_BASE_NS);
    }

    #[test]
    fn straggler_only_moves_the_clock() {
        let plan = FaultPlan::new(vec![Fault {
            tick: 1,
            src: 0,
            dst: ANY_DST,
            kind: FaultKind::Straggler { delay_ns: 12_345 },
            attempts: 1,
        }]);
        plan.deliver(1, 0, 0, &WireBuf::Dense(vec![1.0]));
        assert_eq!(plan.stats(), FaultStats { clock_ns: 12_345, ..FaultStats::default() });
    }

    #[test]
    fn crashes_are_idempotent_per_rank() {
        let plan = FaultPlan::new(vec![
            Fault { tick: 2, src: 1, dst: ANY_DST, kind: FaultKind::CrashRank, attempts: 1 },
            Fault { tick: 2, src: 1, dst: ANY_DST, kind: FaultKind::CrashRank, attempts: 1 },
        ]);
        assert_eq!(plan.crashed_at(0), vec![]);
        assert_eq!(plan.crashed_at(2), vec![1]);
        assert_eq!(plan.crashed_at(2), vec![]); // already failed
        assert!(plan.is_failed(1));
        assert_eq!(plan.stats().failovers, 1);
    }

    #[test]
    fn seeded_plans_replay() {
        let a = FaultPlan::seeded(99, 4, 10, 6);
        let b = FaultPlan::seeded(99, 4, 10, 6);
        assert_eq!(a.faults().len(), 6);
        for (fa, fb) in a.faults().iter().zip(b.faults()) {
            assert_eq!((fa.tick, fa.src, fa.dst, fa.attempts), (fb.tick, fb.src, fb.dst, fb.attempts));
            assert_eq!(fa.kind, fb.kind);
        }
        assert_ne!(
            FaultPlan::seeded(100, 4, 10, 6).faults().iter().map(|f| f.tick).collect::<Vec<_>>(),
            a.faults().iter().map(|f| f.tick).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn unarmed_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(!plan.armed());
        plan.deliver(0, 0, 0, &WireBuf::Dense(vec![1.0]));
        assert_eq!(plan.stats(), FaultStats::default());
    }

    #[test]
    fn wire_tick_separates_coordinates() {
        let mut seen = std::collections::BTreeSet::new();
        for kk in 0..4 {
            for c in 0..4 {
                for b in [false, true] {
                    assert!(seen.insert(wire_tick(kk, c, b)));
                }
            }
        }
    }
}
