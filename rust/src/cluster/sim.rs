//! End-to-end training-step simulator — the Tables 2–3 generator.
//!
//! Costs one DeepSeek-V3 pipeline stage per microbatch from first
//! principles (GEMM FLOPs at the recipe's precision, HBM passes for every
//! data-movement/cast kernel taken from the recipe's *dataflow graph*,
//! DeepEP-style all-to-all from [`crate::cluster::comm`]), then rolls up
//! through the 1F1B schedule and the memory model.
//!
//! Everything recipe-specific is derived from the same [`Variant`] graphs
//! the dataflow tests pin down — the simulator cannot silently diverge
//! from the audited cast accounting.

use crate::cluster::comm::{a2a_latency, Wire};
use crate::cluster::ep_exec::{EpForward, EpShape};
use crate::cluster::memory::{
    layers_per_stage, memory_report, AcMode, MemReport, Workload, DEFAULT_WORKLOAD,
};
use crate::cluster::model_cfg::ModelCfg;
use crate::cluster::topology::Layout;
use crate::dataflow::{build, OpKind, Variant};
use crate::moe::layer::Recipe;

/// Result of one simulated configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    /// EP group size.
    pub ep: usize,
    /// Pipeline stages.
    pub pp: usize,
    /// tokens / GPU / second.
    pub tgs: f64,
    /// Modeled memory footprint (GiB).
    pub mem_gb: f64,
    /// Does the footprint exceed HBM?
    pub oom: bool,
    /// Modeled seconds per global step.
    pub step_s: f64,
    /// Pipeline bubble fraction.
    pub bubble_frac: f64,
    /// per-microbatch stage decomposition (s)
    pub t_gemm: f64,
    /// All-to-all seconds.
    pub t_comm: f64,
    /// Data-movement (permute/pad) seconds.
    pub t_move: f64,
    /// Explicit-cast seconds.
    pub t_cast: f64,
}

fn variant_of(recipe: Recipe) -> Variant {
    match recipe {
        Recipe::Bf16 => Variant::Bf16,
        Recipe::Blockwise => Variant::TeBlockwise,
        Recipe::Fp8Flow => Variant::Fp8Flow,
    }
}

/// Per-microbatch, per-stage cost decomposition (seconds).
struct StageCost {
    gemm_fwd: f64,
    gemm_bwd: f64,
    comm_fwd: f64,
    comm_bwd: f64,
    move_fwd: f64,
    move_bwd: f64,
    cast_fwd: f64,
    cast_bwd: f64,
}

fn hbm_pass(l: &Layout, bytes: f64) -> f64 {
    12.0 * l.hw.launch_overhead + bytes / l.hw.hbm_bw
}

fn stage_cost(m: &ModelCfg, l: &Layout, w: &Workload, recipe: Recipe) -> StageCost {
    let hw = &l.hw;
    let layers = layers_per_stage(m, l) as f64;
    let tokens = (w.seq * w.micro_batch) as f64;
    let te = tokens * m.top_k as f64; // expanded (dispatched) tokens
    let d = m.d_model as f64;
    let h = m.moe_ffn as f64;
    let g = build(variant_of(recipe));

    // ---- GEMM compute ----
    let expert_flops_fwd = 2.0 * te * (3.0 * d * h); // fc1(gate+up)+fc2
    let dense_flops_fwd = 2.0 * tokens * m.dense_params_per_layer() as f64;
    let (moe_peak, moe_eff) = match recipe {
        Recipe::Bf16 => (hw.bf16_flops, hw.gemm_efficiency),
        // TE-style blockwise FP8 grouped GEMM realizes only a ~1.1×
        // speedup over BF16 at MoE shapes: per-GEMM quantize syncs and
        // fragmented launches waste most of the 2× tensor-core peak —
        // this is the paper's own headline negative result ("naive FP8
        // kernel replacement yields only a 3% gain").
        Recipe::Blockwise => (hw.bf16_flops * 1.1, hw.gemm_efficiency),
        // DeepGEMM-class persistent kernels with fine-grained scaling
        // realize ~1.6× of BF16 (2× peak · 0.8 scaling/epilogue cost).
        Recipe::Fp8Flow => (hw.fp8_flops, hw.gemm_efficiency * 0.8),
    };
    let gemm_fwd = layers
        * (expert_flops_fwd / (moe_peak * moe_eff)
            + dense_flops_fwd / (hw.bf16_flops * hw.gemm_efficiency));
    let gemm_bwd = 2.0 * gemm_fwd; // dgrad + wgrad

    // ---- all-to-all (dispatch + combine, from the graph's wire types) ----
    let a2a = |node_fp8: bool| -> f64 {
        let wire = if node_fp8 { Wire::Fp8 } else { Wire::Bf16 };
        a2a_latency(l, te as usize, m.d_model, wire)
    };
    let mut comm_fwd = 0.0;
    let mut comm_bwd = 0.0;
    for n in &g.nodes {
        if n.op == OpKind::AllToAll {
            let t = a2a(n.out_dtype == crate::dataflow::Dtype::Fp8);
            if n.backward {
                comm_bwd += layers * t;
            } else {
                comm_fwd += layers * t;
            }
        }
    }

    // ---- data movement (permute/pad family) ----
    let elt = |fp8: bool| if fp8 { 1.03 } else { 2.0 };
    let mut move_fwd = 0.0;
    let mut move_bwd = 0.0;
    for n in &g.nodes {
        let bytes = match n.op {
            OpKind::Permute | OpKind::Pad | OpKind::Unpermute | OpKind::Unpad => {
                // unfused: each op is a full read+write pass
                2.0 * te * d * elt(n.out_dtype == crate::dataflow::Dtype::Fp8)
            }
            OpKind::FusedPermutePad | OpKind::FusedUnpermuteUnpad => {
                2.0 * te * d * elt(n.out_dtype == crate::dataflow::Dtype::Fp8)
            }
            OpKind::SwiGlu | OpKind::FusedSwiGluQuant => 2.0 * te * h * 2.0 + te * h * 2.0,
            OpKind::SwiGluBwd | OpKind::FusedSwiGluBwdQuant => 3.0 * te * h * 2.0 + 2.0 * te * h * 2.0,
            OpKind::DirectTranspose => 2.0 * te * h * 1.03, // u8 in, u8 out
            OpKind::NaiveTransposeRequant => {
                // dequant pass + transpose pass + requant pass, bf16 middle
                2.0 * (te * h * 1.0 + te * h * 2.0) + 2.0 * te * h * 2.0
            }
            _ => 0.0,
        };
        if bytes > 0.0 {
            let t = layers * hbm_pass(l, bytes);
            if n.backward {
                move_bwd += t;
            } else {
                move_fwd += t;
            }
        }
    }

    // ---- explicit cast kernels ----
    let mut cast_fwd = 0.0;
    let mut cast_bwd = 0.0;
    for n in &g.nodes {
        if n.op.is_explicit_cast() {
            // a cast reads + writes roughly a [te, d] tensor
            let bytes = te * d * 3.0;
            let t = layers * hbm_pass(l, bytes);
            if n.backward {
                cast_bwd += t;
            } else {
                cast_fwd += t;
            }
        }
    }

    StageCost { gemm_fwd, gemm_bwd, comm_fwd, comm_bwd, move_fwd, move_bwd, cast_fwd, cast_bwd }
}

/// Simulate one (recipe, EP×PP, AC) configuration of Tables 2–3.
pub fn simulate(m: &ModelCfg, ep: usize, pp: usize, recipe: Recipe, ac: AcMode) -> SimResult {
    let l = Layout::new(ep, pp);
    let w = DEFAULT_WORKLOAD;
    let c = stage_cost(m, &l, &w, recipe);

    let fwd = c.gemm_fwd + c.comm_fwd + c.move_fwd + c.cast_fwd;
    let mut bwd = c.gemm_bwd + c.comm_bwd + c.move_bwd + c.cast_bwd;
    if ac == AcMode::Full {
        // full recompute replays the forward (compute + movement + casts +
        // the re-dispatch all-to-all) before the backward of each layer
        bwd += fwd;
    }
    let pt = crate::cluster::schedule::one_f_one_b(fwd, bwd, pp, w.n_micro);
    let mem: MemReport = memory_report(m, &l, &w, recipe, ac);
    let oom = mem.oom(&l);

    // Each EP rank runs its own token stream (the EP group doubles as the
    // data-parallel group): EP parallel pipelines of depth PP.
    let global_tokens = (w.seq * w.micro_batch * w.n_micro) as f64 * l.ep as f64;
    let tgs = if oom { 0.0 } else { global_tokens / (pt.step * l.n_gpus() as f64) };
    SimResult {
        ep,
        pp,
        tgs,
        mem_gb: mem.total_gb(),
        oom,
        step_s: pt.step,
        bubble_frac: pt.bubble_frac,
        t_gemm: c.gemm_fwd + c.gemm_bwd,
        t_comm: c.comm_fwd + c.comm_bwd,
        t_move: c.move_fwd + c.move_bwd,
        t_cast: c.cast_fwd + c.cast_bwd,
    }
}

/// What the analytic model predicts for one executed `epshard`
/// configuration (seconds): the comm model's dispatch/combine all-to-all
/// plus the GEMM term for the per-rank expert work.
#[derive(Clone, Copy, Debug)]
pub struct ModeledEp {
    /// Modeled dispatch all-to-all seconds.
    pub dispatch_s: f64,
    /// Modeled per-rank expert GEMM seconds.
    pub expert_s: f64,
    /// Modeled combine all-to-all seconds.
    pub combine_s: f64,
}

/// Per-op unit costs fitted from recorded traces — the `calibrate`
/// subcommand's output ([`crate::obs::calibrate`]), persisted in
/// `runs/calibrate.json`. Where [`modeled_ep_stages`] costs stages from
/// hand-set H100 constants, a `CostTable` costs them from *this
/// machine's measured spans*, which is what turns the projection sweeps
/// from illustrative into predictive.
///
/// Unit convention: every cost multiplies an analytic op count (tokens
/// routed, bytes moved, FLOPs executed) into **total busy seconds summed
/// across simulated ranks** — the same aggregation the trace's per-stage
/// span sums use, so fit residuals are an apples-to-apples comparison.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostTable {
    /// Router seconds per routed token.
    pub route_s_per_token: f64,
    /// Entry-quantization seconds per input byte (Fp8Flow only).
    pub quant_s_per_byte: f64,
    /// Wire-pack seconds per wire byte (payload + sidecar).
    pub pack_s_per_byte: f64,
    /// All-to-all seconds per wire byte.
    pub a2a_s_per_byte: f64,
    /// Assemble (unpack) seconds per wire byte.
    pub assemble_s_per_byte: f64,
    /// Expert grouped-GEMM seconds per FLOP.
    pub gemm_s_per_flop: f64,
    /// Combine-reduce seconds per combined byte.
    pub combine_s_per_byte: f64,
}

impl CostTable {
    /// Analytic dispatch wire bytes for one EP forward at `shape`
    /// (per-slot sent rows bounded by total capacity; FP8 wire ships
    /// 1 B/element + a 1 B/128-element UE8M0 sidecar, dense ships
    /// BF16-accounted rows).
    pub fn dispatch_wire_bytes(recipe: Recipe, shape: &EpShape) -> f64 {
        let rows = shape.tokens.min(shape.n_experts * shape.capacity) as f64;
        let d = shape.d_model as f64;
        let per_slot = if recipe == Recipe::Fp8Flow {
            rows * d + rows * (shape.d_model as f64 / 128.0).ceil()
        } else {
            rows * d * 2.0
        };
        shape.top_k as f64 * per_slot
    }

    /// Analytic expert FLOPs for one EP forward at `shape`: every slot
    /// runs the padded `E·capacity` rows through fc1(gate+up)+fc2.
    pub fn expert_flops(shape: &EpShape) -> f64 {
        let rows = (shape.n_experts * shape.capacity) as f64;
        shape.top_k as f64 * rows * 6.0 * shape.d_model as f64 * shape.ffn as f64
    }

    /// Predict the stage costs of one EP forward at `shape` from the
    /// fitted table (total busy seconds across ranks; `dispatch_s` is
    /// pack + a2a + assemble, entry quant excluded — same stage split as
    /// [`modeled_ep_stages`]).
    pub fn predict_ep_stages(&self, recipe: Recipe, shape: &EpShape) -> ModeledEp {
        let wire = Self::dispatch_wire_bytes(recipe, shape);
        let combine_bytes = (shape.tokens.min(shape.n_experts * shape.capacity)
            * shape.top_k
            * shape.d_model
            * 2) as f64;
        ModeledEp {
            dispatch_s: (self.pack_s_per_byte + self.a2a_s_per_byte + self.assemble_s_per_byte)
                * wire,
            expert_s: self.gemm_s_per_flop * Self::expert_flops(shape),
            combine_s: self.combine_s_per_byte * combine_bytes,
        }
    }

    /// JSON rendering for `runs/calibrate.json`.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("route_s_per_token", self.route_s_per_token)
            .set("quant_s_per_byte", self.quant_s_per_byte)
            .set("pack_s_per_byte", self.pack_s_per_byte)
            .set("a2a_s_per_byte", self.a2a_s_per_byte)
            .set("assemble_s_per_byte", self.assemble_s_per_byte)
            .set("gemm_s_per_flop", self.gemm_s_per_flop)
            .set("combine_s_per_byte", self.combine_s_per_byte)
    }
}

/// Cost the stages of an executed EP forward with the same model that
/// generates Tables 1–3, at the executed shape. The executed runtime
/// pays one dispatch + combine all-to-all **per top-k slot** (each slot
/// ships ~`tokens` rows), so the α/sync term is charged per slot here
/// too — charging one expanded `tokens·top_k` a2a would undercount it
/// by `top_k`×. Expert GEMMs cover all slots, sharded across `ranks`.
pub fn modeled_ep_stages(ranks: usize, recipe: Recipe, shape: &EpShape) -> ModeledEp {
    let l = Layout::new(ranks, 1);
    let te = shape.tokens * shape.top_k;
    let slots = shape.top_k as f64;
    let wire = if recipe == Recipe::Fp8Flow { Wire::Fp8 } else { Wire::Bf16 };
    let dispatch_s = slots * a2a_latency(&l, shape.tokens, shape.d_model, wire);
    // combine stays BF16 in every recipe (§3.3: gradient-safe combine)
    let combine_s = slots * a2a_latency(&l, shape.tokens, shape.d_model, Wire::Bf16);
    let (peak, eff) = match recipe {
        Recipe::Bf16 => (l.hw.bf16_flops, l.hw.gemm_efficiency),
        Recipe::Blockwise => (l.hw.bf16_flops * 1.1, l.hw.gemm_efficiency),
        Recipe::Fp8Flow => (l.hw.fp8_flops, l.hw.gemm_efficiency * 0.8),
    };
    let flops = 2.0 * te as f64 * 3.0 * shape.d_model as f64 * shape.ffn as f64 / ranks as f64;
    ModeledEp { dispatch_s, expert_s: flops / (peak * eff), combine_s }
}

/// Render one executed EP forward side by side with the analytic model —
/// measured wall-clock (this machine) vs modeled time (H100 cluster).
/// Absolute ratios differ by the hardware gap; the calibration signal is
/// the *relative* shape (dispatch:expert:combine, and FP8-vs-BF16 wire
/// ratios across recipes) — see `rust/EXPERIMENTS.md` §"Measured vs
/// modeled EP dispatch".
pub fn ep_measured_vs_modeled(
    recipe: Recipe,
    ranks: usize,
    shape: &EpShape,
    f: &EpForward,
) -> String {
    let m = modeled_ep_stages(ranks, recipe, shape);
    let mut s = String::new();
    s.push_str(&format!(
        "== epshard {recipe:?}: R={ranks} tokens={} d={} E={} cap={} top_k={} ==\n",
        shape.tokens, shape.d_model, shape.n_experts, shape.capacity, shape.top_k
    ));
    s.push_str(&format!(
        "{:<10} {:>13} {:>13} {:>12}\n",
        "stage", "measured_ms", "modeled_ms", "meas/model"
    ));
    let rows = [
        ("dispatch", f.stages.dispatch_s, m.dispatch_s),
        ("expert", f.stages.expert_s, m.expert_s),
        ("combine", f.stages.combine_s, m.combine_s),
    ];
    for (name, meas, model) in rows {
        s.push_str(&format!(
            "ROW {:<6} {:>13.4} {:>13.4} {:>11.1}x\n",
            name,
            meas * 1e3,
            model * 1e3,
            meas / model
        ));
    }
    s.push_str(&format!(
        "    route {:.4} ms, entry-quant {:.4} ms; total {:.4} ms\n",
        f.stages.route_s * 1e3,
        f.stages.quant_s * 1e3,
        f.stages.total_s() * 1e3
    ));
    s.push_str(&format!(
        "    wire: payload {} B + sidecar {} B in {} buffers (dispatch), {} B (combine)\n",
        f.dispatch_payload_bytes, f.dispatch_sidecar_bytes, f.dispatch_buffers, f.combine_bytes
    ));
    let imb = per_rank_imbalance(&f.rank_expert_s);
    s.push_str(&format!(
        "    per-rank expert ms: [{}]  (max/mean imbalance {:.2}x)\n",
        f.rank_expert_s
            .iter()
            .map(|v| format!("{:.3}", v * 1e3))
            .collect::<Vec<_>>()
            .join(", "),
        imb
    ));
    s
}

/// Modeled serving throughput (tokens/s) for one micro-batch `shape` on
/// the H100 cluster model: the batch's tokens over the serialized
/// dispatch + expert + combine stage total from [`modeled_ep_stages`].
/// The serving loop is one EP forward per flush tick, so the modeled
/// steady-state rate is exactly the per-tick rate at the mean tick shape.
pub fn modeled_serve_tokens_per_s(ranks: usize, recipe: Recipe, shape: &EpShape) -> f64 {
    let m = modeled_ep_stages(ranks, recipe, shape);
    shape.tokens as f64 / (m.dispatch_s + m.expert_s + m.combine_s)
}

/// Measured-vs-modeled serving throughput row for the `serve` report.
/// Same caveat as [`ep_measured_vs_modeled`]: measured is this machine's
/// wall clock, modeled is the H100 cluster — the calibration signal is
/// the relative shape across recipes/ranks, not the absolute ratio.
pub fn serve_measured_vs_modeled(
    recipe: Recipe,
    ranks: usize,
    shape: &EpShape,
    measured_tokens_per_s: f64,
) -> String {
    let modeled = modeled_serve_tokens_per_s(ranks, recipe, shape);
    format!(
        "ROW serve-model {:<9} R={ranks} mean-batch {:>5} tok | measured {:>12.0} tok/s | \
         modeled {:>12.0} tok/s | meas/model {:.3}x\n",
        format!("{recipe:?}"),
        shape.tokens,
        measured_tokens_per_s,
        modeled,
        measured_tokens_per_s / modeled
    )
}

/// Max/mean ratio of per-rank stage times (1.0 = perfectly balanced).
pub fn per_rank_imbalance(rank_s: &[f64]) -> f64 {
    if rank_s.is_empty() {
        return 1.0;
    }
    let mean = rank_s.iter().sum::<f64>() / rank_s.len() as f64;
    let max = rank_s.iter().cloned().fold(0.0f64, f64::max);
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

/// Modeled wall-clock of a stage sequence split into `chunks` equal
/// pieces and run through an ideal software pipeline: the fill/drain
/// costs one chunk of every stage (`sum / C`), the steady state is
/// bounded by the slowest stage (`max · (C-1) / C`). `C = 1` degenerates
/// to the plain serial sum; `C → ∞` approaches the slowest stage —
/// perfect hiding of everything else behind the bottleneck.
pub fn pipelined_wall(stages: &[f64], chunks: usize) -> f64 {
    assert!(chunks >= 1, "need at least one pipeline chunk");
    let sum: f64 = stages.iter().sum();
    let max = stages.iter().cloned().fold(0.0f64, f64::max);
    let c = chunks as f64;
    sum / c + max * (c - 1.0) / c
}

/// Measured-vs-modeled **overlap efficiency**: a serialized and an
/// overlapped executed EP forward of the same configuration, side by
/// side with the pipelined analytic model. Definitions (all from
/// measured pipeline wall-clock, route/entry-quant excluded since they
/// run identically in both schedules):
///
/// * `hideable  = min(dispatch + combine, expert)` from the serialized
///   run — the most comm (or compute, whichever is smaller) a perfect
///   overlap could hide;
/// * `hidden    = serialized_wall - overlapped_wall` — what the step
///   graph actually hid;
/// * `efficiency = hidden / hideable` — 1.0 means the measured overlap
///   achieves everything the sim's full-hiding assumption grants it.
pub fn ep_overlap_report(
    recipe: Recipe,
    ranks: usize,
    shape: &EpShape,
    serial: &EpForward,
    over: &EpForward,
) -> String {
    // modeled_ep_stages already totals over the top-k slots
    let m = modeled_ep_stages(ranks, recipe, shape);
    let model_stages = [m.dispatch_s, m.expert_s, m.combine_s];
    let model_serial = model_stages.iter().sum::<f64>();
    let model_over = pipelined_wall(&model_stages, over.chunks.max(1));
    let meas_serial = serial.pipeline_wall_s;
    let meas_over = over.pipeline_wall_s;

    let comm = serial.stages.dispatch_s + serial.stages.combine_s;
    let hideable = comm.min(serial.stages.expert_s);
    let hidden = meas_serial - meas_over;
    let efficiency = if hideable > 0.0 { hidden / hideable } else { 0.0 };

    let mut s = String::new();
    s.push_str(&format!(
        "== overlap {recipe:?}: R={ranks} C={} tokens={} d={} E={} top_k={} ==\n",
        over.chunks, shape.tokens, shape.d_model, shape.n_experts, shape.top_k
    ));
    s.push_str(&format!(
        "{:<14} {:>13} {:>13}\n",
        "schedule", "measured_ms", "modeled_ms"
    ));
    s.push_str(&format!(
        "ROW serialized {:>13.4} {:>13.4}\n",
        meas_serial * 1e3,
        model_serial * 1e3
    ));
    s.push_str(&format!(
        "ROW overlapped {:>13.4} {:>13.4}\n",
        meas_over * 1e3,
        model_over * 1e3
    ));
    s.push_str(&format!(
        "ROW speedup    {:>12.3}x {:>12.3}x\n",
        meas_serial / meas_over,
        model_serial / model_over
    ));
    s.push_str(&format!(
        "    hideable {:.4} ms, hidden {:.4} ms, overlap efficiency {:.3}\n",
        hideable * 1e3,
        hidden * 1e3,
        efficiency
    ));
    // Satellite of the obs layer: every stage reports BOTH summed busy
    // time (rank-seconds of work) and wall time (interval union of that
    // stage's spans). Serialized schedules have busy == wall by
    // construction; overlapped schedules show wall < busy exactly where
    // the step graph interleaved ranks/chunks.
    let stage_rows: [(&str, f64, f64, f64, f64); 3] = [
        (
            "dispatch",
            serial.stages.dispatch_s,
            serial.dispatch_wall_s,
            over.stages.dispatch_s,
            over.dispatch_wall_s,
        ),
        (
            "expert",
            serial.stages.expert_s,
            serial.expert_wall_s,
            over.stages.expert_s,
            over.expert_wall_s,
        ),
        (
            "combine",
            serial.stages.combine_s,
            serial.combine_wall_s,
            over.stages.combine_s,
            over.combine_wall_s,
        ),
    ];
    for (name, sb, sw, ob, ow) in stage_rows {
        s.push_str(&format!(
            "    stage {name:<8} busy/wall ms: serialized {:.4}/{:.4}, overlapped {:.4}/{:.4}\n",
            sb * 1e3,
            sw * 1e3,
            ob * 1e3,
            ow * 1e3
        ));
    }
    let fmt_slots = |walls: &[f64]| {
        walls.iter().map(|v| format!("{:.3}", v * 1e3)).collect::<Vec<_>>().join(", ")
    };
    s.push_str(&format!(
        "    per-slot wall ms: serialized [{}], overlapped [{}]\n",
        fmt_slots(&serial.slot_wall_s),
        fmt_slots(&over.slot_wall_s)
    ));
    s
}

/// The paper's Tables 2–3 values for side-by-side reporting:
/// (recipe, ep, tgs, mem_gb) — `None` = OOM.
pub const TABLE2_PAPER: [(&str, usize, f64, f64); 9] = [
    ("bf16", 8, 1109.0, 39.0),
    ("bf16", 16, 939.0, 36.0),
    ("bf16", 32, 671.0, 43.0),
    ("blockwise", 8, 1146.0, 37.0),
    ("blockwise", 16, 938.0, 41.0),
    ("blockwise", 32, 644.0, 51.0),
    ("fp8flow", 8, 1176.0, 37.0),
    ("fp8flow", 16, 1012.0, 39.0),
    ("fp8flow", 32, 779.0, 49.0),
];

/// Table 3 reference rows from the paper: `(recipe, EP, Some((TGS, MFU%)))`; `None` marks configurations the paper does not report.
pub const TABLE3_PAPER: [(&str, usize, Option<(f64, f64)>); 9] = [
    ("bf16", 8, Some((1178.0, 64.0))),
    ("bf16", 16, Some((1055.0, 71.0))),
    ("bf16", 32, None),
    ("blockwise", 8, Some((1178.0, 73.0))),
    ("blockwise", 16, Some((1031.0, 77.0))),
    ("blockwise", 32, None),
    ("fp8flow", 8, Some((1193.0, 56.0))),
    ("fp8flow", 16, Some((1111.0, 66.0))),
    ("fp8flow", 32, Some((912.0, 75.0))),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::model_cfg::DEEPSEEK_V3;

    fn run(recipe: Recipe, ep: usize, ac: AcMode) -> SimResult {
        simulate(&DEEPSEEK_V3, ep, 256 / ep, recipe, ac)
    }

    #[test]
    fn fp8flow_wins_everywhere_table2() {
        for ep in [8, 16, 32] {
            let bf16 = run(Recipe::Bf16, ep, AcMode::Full);
            let block = run(Recipe::Blockwise, ep, AcMode::Full);
            let flow = run(Recipe::Fp8Flow, ep, AcMode::Full);
            assert!(flow.tgs > bf16.tgs, "EP{ep}: flow {} vs bf16 {}", flow.tgs, bf16.tgs);
            assert!(flow.tgs > block.tgs, "EP{ep}: flow {} vs blockwise {}", flow.tgs, block.tgs);
        }
    }

    #[test]
    fn gap_over_blockwise_widens_with_ep() {
        // paper: +3% (EP8) → +8% (EP16) → +21% (EP32)
        let gain = |ep| {
            let b = run(Recipe::Blockwise, ep, AcMode::Full).tgs;
            let f = run(Recipe::Fp8Flow, ep, AcMode::Full).tgs;
            f / b
        };
        let (g8, g16, g32) = (gain(8), gain(16), gain(32));
        assert!(g8 < g16 && g16 < g32, "gains should widen: {g8:.3} {g16:.3} {g32:.3}");
        assert!(g8 > 1.0 && g32 > 1.10, "EP32 gain should be large: {g32:.3}");
    }

    #[test]
    fn blockwise_loses_to_bf16_at_high_ep() {
        // the paper's sign flip: naive FP8 kernel replacement is SLOWER
        // than BF16 at EP32 (644 vs 671 TGS) — cast overhead + BF16 comm
        let bf16 = run(Recipe::Bf16, 32, AcMode::Full);
        let block = run(Recipe::Blockwise, 32, AcMode::Full);
        assert!(
            block.tgs < bf16.tgs * 1.02,
            "blockwise {} should not beat bf16 {} at EP32",
            block.tgs,
            bf16.tgs
        );
    }

    #[test]
    fn table3_oom_pattern() {
        assert!(run(Recipe::Bf16, 32, AcMode::SelMoeExpert).oom);
        assert!(run(Recipe::Blockwise, 32, AcMode::SelMoeExpert).oom);
        let flow = run(Recipe::Fp8Flow, 32, AcMode::SelMoeExpert);
        assert!(!flow.oom);
        assert!(flow.tgs > 0.0);
    }

    #[test]
    fn ac_sel_is_faster_but_heavier() {
        for r in [Recipe::Bf16, Recipe::Fp8Flow] {
            let full = run(r, 8, AcMode::Full);
            let sel = run(r, 8, AcMode::SelMoeExpert);
            assert!(sel.tgs > full.tgs, "{r:?}: sel {} vs full {}", sel.tgs, full.tgs);
            assert!(sel.mem_gb > full.mem_gb);
        }
    }

    #[test]
    fn absolute_tgs_same_order_as_paper() {
        // calibration sanity: within 2.5× of the paper's BF16 EP8 number
        let bf16 = run(Recipe::Bf16, 8, AcMode::Full);
        assert!(
            (443.0..2772.0).contains(&bf16.tgs),
            "BF16 EP8 TGS {} too far from paper's 1109",
            bf16.tgs
        );
    }

    #[test]
    fn tgs_decreases_with_ep() {
        for r in [Recipe::Bf16, Recipe::Fp8Flow] {
            let t8 = run(r, 8, AcMode::Full).tgs;
            let t16 = run(r, 16, AcMode::Full).tgs;
            let t32 = run(r, 32, AcMode::Full).tgs;
            assert!(t8 > t16 && t16 > t32, "{r:?}: {t8} {t16} {t32}");
        }
    }

    #[test]
    fn modeled_ep_stages_have_the_right_shape() {
        let shape = EpShape {
            tokens: 4096,
            d_model: 1024,
            ffn: 1024,
            n_experts: 8,
            top_k: 2,
            capacity: 1024,
        };
        let flow = modeled_ep_stages(4, Recipe::Fp8Flow, &shape);
        let bf16 = modeled_ep_stages(4, Recipe::Bf16, &shape);
        // FP8 wire beats BF16 on dispatch; combine (BF16 both) is equal
        assert!(flow.dispatch_s < bf16.dispatch_s);
        assert_eq!(flow.combine_s, bf16.combine_s);
        // expert work shrinks with more ranks
        let flow8 = modeled_ep_stages(8, Recipe::Fp8Flow, &shape);
        assert!(flow8.expert_s < flow.expert_s);
    }

    #[test]
    fn modeled_serve_throughput_prefers_the_fp8_wire() {
        let shape = EpShape {
            tokens: 256,
            d_model: 256,
            ffn: 256,
            n_experts: 8,
            top_k: 2,
            capacity: 64,
        };
        let flow = modeled_serve_tokens_per_s(2, Recipe::Fp8Flow, &shape);
        let bf16 = modeled_serve_tokens_per_s(2, Recipe::Bf16, &shape);
        assert!(flow > 0.0 && bf16 > 0.0);
        // FP8 dispatch wire + faster expert GEMM ⇒ higher modeled rate
        assert!(flow > bf16, "flow {flow} vs bf16 {bf16}");
        let rep = serve_measured_vs_modeled(Recipe::Fp8Flow, 2, &shape, flow);
        assert!(rep.starts_with("ROW serve-model"), "bad report row: {rep}");
    }

    #[test]
    fn imbalance_metric() {
        assert_eq!(per_rank_imbalance(&[]), 1.0);
        assert_eq!(per_rank_imbalance(&[2.0, 2.0]), 1.0);
        assert_eq!(per_rank_imbalance(&[3.0, 1.0]), 1.5);
    }

    #[test]
    fn pipelined_wall_closed_forms() {
        let stages = [3.0, 6.0, 1.0];
        // C = 1: the plain serial sum
        assert_eq!(pipelined_wall(&stages, 1), 10.0);
        // monotone non-increasing in C, bounded below by the slowest stage
        let mut prev = f64::INFINITY;
        for c in 1..=16 {
            let w = pipelined_wall(&stages, c);
            assert!(w <= prev + 1e-12, "C={c}: {w} > {prev}");
            assert!(w >= 6.0, "C={c}: {w} below the bottleneck stage");
            prev = w;
        }
        // exact closed form at C = 2: 10/2 + 6/2 = 8
        assert_eq!(pipelined_wall(&stages, 2), 8.0);
        // C → ∞ approaches max(stages)
        assert!((pipelined_wall(&stages, 10_000) - 6.0).abs() < 1e-2);
    }

    #[test]
    #[should_panic(expected = "at least one pipeline chunk")]
    fn pipelined_wall_rejects_zero_chunks() {
        pipelined_wall(&[1.0], 0);
    }

    #[test]
    fn overlap_report_has_the_grepable_markers() {
        use crate::moe::layer::{MoeWeights, PreparedWeights};
        use crate::util::mat::Mat;
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(40);
        let x = Mat::randn(64, 64, 0.5, &mut rng);
        let w = PreparedWeights::new(MoeWeights::random(64, 48, 4, &mut rng), Recipe::Fp8Flow);
        let cfg = crate::cluster::ep_exec::EpConfig::serial(2, 2, 24, 2);
        let shape = EpShape::of(&x, &w, &cfg);
        let serial = crate::cluster::ep_exec::ep_forward(&x, &w, &cfg);
        let over = crate::cluster::ep_exec::ep_forward(&x, &w, &cfg.with_pipeline(2, true));
        let rep = ep_overlap_report(Recipe::Fp8Flow, 2, &shape, &serial, &over);
        for marker in [
            "== overlap",
            "ROW serialized",
            "ROW overlapped",
            "ROW speedup",
            "    hideable",
            "overlap efficiency",
            "    stage dispatch",
            "    stage expert",
            "    stage combine",
            "busy/wall ms",
            "    per-slot wall ms",
        ] {
            assert!(rep.contains(marker), "missing {marker:?} in:\n{rep}");
        }
    }

    #[test]
    fn cost_table_predicts_linearly_in_its_costs() {
        let shape = EpShape {
            tokens: 64,
            d_model: 64,
            ffn: 48,
            n_experts: 4,
            top_k: 2,
            capacity: 24,
        };
        let unit = CostTable {
            route_s_per_token: 1.0,
            quant_s_per_byte: 1.0,
            pack_s_per_byte: 1.0,
            a2a_s_per_byte: 1.0,
            assemble_s_per_byte: 1.0,
            gemm_s_per_flop: 1.0,
            combine_s_per_byte: 1.0,
        };
        let p = unit.predict_ep_stages(Recipe::Fp8Flow, &shape);
        let wire = CostTable::dispatch_wire_bytes(Recipe::Fp8Flow, &shape);
        // dispatch = (pack + a2a + assemble) × wire bytes at unit costs
        assert!((p.dispatch_s - 3.0 * wire).abs() < 1e-6);
        assert!((p.expert_s - CostTable::expert_flops(&shape)).abs() < 1e-3);
        assert!(p.combine_s > 0.0);
        // doubling every cost doubles every prediction
        let double = CostTable {
            route_s_per_token: 2.0,
            quant_s_per_byte: 2.0,
            pack_s_per_byte: 2.0,
            a2a_s_per_byte: 2.0,
            assemble_s_per_byte: 2.0,
            gemm_s_per_flop: 2.0,
            combine_s_per_byte: 2.0,
        };
        let q = double.predict_ep_stages(Recipe::Fp8Flow, &shape);
        assert!((q.dispatch_s - 2.0 * p.dispatch_s).abs() < 1e-6);
        assert!((q.expert_s - 2.0 * p.expert_s).abs() < 1e-3);
        assert!((q.combine_s - 2.0 * p.combine_s).abs() < 1e-6);
        // dense wire costs more than FP8 wire (2 B/elt vs 1 B + sidecar)
        assert!(
            CostTable::dispatch_wire_bytes(Recipe::Bf16, &shape)
                > CostTable::dispatch_wire_bytes(Recipe::Fp8Flow, &shape)
        );
        let j = unit.to_json().render();
        for key in ["route_s_per_token", "gemm_s_per_flop", "combine_s_per_byte"] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn cast_time_ordering_matches_cast_counts() {
        let bf16 = run(Recipe::Bf16, 16, AcMode::Full);
        let block = run(Recipe::Blockwise, 16, AcMode::Full);
        let flow = run(Recipe::Fp8Flow, 16, AcMode::Full);
        assert_eq!(bf16.t_cast, 0.0);
        assert!(flow.t_cast < block.t_cast);
    }
}
