//! Miniature property-testing harness (substitute for `proptest`, which is
//! not vendored in this image).
//!
//! Usage:
//! ```
//! use fp8_flow_moe::util::prop::{props, Gen};
//! props("addition commutes", 256, |g: &mut Gen| {
//!     let (a, b) = (g.f32_normal(), g.f32_normal());
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Every run is seeded; on failure the panic message carries the case seed
//! so the exact case can be replayed with `PROP_SEED=<seed>`. `PROP_CASES`
//! scales the number of cases (e.g. `PROP_CASES=10000` for a soak run).

use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Assert two f32 buffers are bitwise identical — the repo-wide
/// bit-exactness contract checker (thread invariance, EP invariance):
/// `-0.0` vs `+0.0` and NaN payloads all count as differences.
pub fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {k}: {x} vs {y}");
    }
}

/// [`assert_bits_eq`] over whole matrices (shape checked first).
pub fn assert_mat_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_bits_eq(&a.data, &b.data, what);
}

/// Case-level generator handed to each property execution.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    /// Standard-normal f32.
    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Finite f32 spanning many binades (log-uniform magnitude, signed),
    /// occasionally exactly zero — the adversarial quantizer input.
    pub fn f32_wide(&mut self) -> f32 {
        match self.rng.below(16) {
            0 => 0.0,
            1 => self.rng.log_uniform_signed(-20.0, -6.0), // deep subnormal region
            2 => self.rng.log_uniform_signed(6.0, 12.0),   // near/above fp8 max
            _ => self.rng.log_uniform_signed(-12.0, 9.0),
        }
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Vector of `n` values from `f`.
    pub fn vec_of(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> f32) -> Vec<f32> {
        (0..n).map(|_| f(self)).collect()
    }
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Run `f` against `cases` random cases (scaled by `PROP_CASES`, overridden
/// to a single case by `PROP_SEED`). Panics with the case seed on failure.
pub fn props(name: &str, cases: usize, f: impl Fn(&mut Gen)) {
    if let Some(seed) = env_u64("PROP_SEED") {
        let mut g = Gen { rng: Rng::seed_from(seed), seed };
        f(&mut g);
        return;
    }
    let cases = env_u64("PROP_CASES").map(|c| c as usize).unwrap_or(cases);
    // Derive per-case seeds from the property name so adding properties
    // does not perturb existing ones.
    let name_hash = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
    });
    for case in 0..cases {
        let seed = name_hash.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::seed_from(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        props("abs is non-negative", 64, |g| {
            let x = g.f32_wide();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn failure_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            props("always fails", 4, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn wide_generator_hits_zero_and_extremes() {
        let mut g = Gen { rng: Rng::seed_from(123), seed: 123 };
        let xs: Vec<f32> = (0..4096).map(|_| g.f32_wide()).collect();
        assert!(xs.iter().any(|&x| x == 0.0));
        assert!(xs.iter().any(|&x| x.abs() > 448.0));
        assert!(xs.iter().any(|&x| x != 0.0 && x.abs() < 2.0_f32.powi(-9)));
    }
}
