//! Miniature property-testing harness (substitute for `proptest`, which is
//! not vendored in this image).
//!
//! Usage:
//! ```
//! use fp8_flow_moe::util::prop::{props, Gen};
//! props("addition commutes", 256, |g: &mut Gen| {
//!     let (a, b) = (g.f32_normal(), g.f32_normal());
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Every run is seeded; on failure the panic message carries the case seed
//! so the exact case can be replayed with `PROP_SEED=<seed>`. `PROP_CASES`
//! scales the number of cases (e.g. `PROP_CASES=10000` for a soak run).

use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// Assert two f32 buffers are bitwise identical — the repo-wide
/// bit-exactness contract checker (thread invariance, EP invariance):
/// `-0.0` vs `+0.0` and NaN payloads all count as differences.
pub fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {k}: {x} vs {y}");
    }
}

/// [`assert_bits_eq`] over whole matrices (shape checked first).
pub fn assert_mat_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_bits_eq(&a.data, &b.data, what);
}

/// Central-difference gradient check: `analytic` must approximate
/// `∂L/∂x` where `L(x) = Σ f(x) ⊙ dy` (the loss is accumulated in f64 to
/// keep the difference quotient out of f32 cancellation noise).
///
/// For each probed index `i`, the symmetric quotient
/// `(L(x + ε·eᵢ) − L(x − ε·eᵢ)) / 2ε` must match `analytic[i]` within
/// `tol · (1 + |analytic[i]|)` — an absolute floor plus a relative term,
/// so the same tolerance works across gradient magnitudes.
///
/// `f` maps the flat input to the flat output; probing a subset keeps the
/// cost at two forward evaluations per probe.
#[allow(clippy::too_many_arguments)]
pub fn gradcheck(
    what: &str,
    f: impl Fn(&[f32]) -> Vec<f32>,
    x: &[f32],
    dy: &[f32],
    analytic: &[f32],
    eps: f32,
    tol: f64,
    probes: &[usize],
) {
    assert_eq!(x.len(), analytic.len(), "{what}: analytic gradient length");
    let loss = |xs: &[f32]| -> f64 {
        let y = f(xs);
        assert_eq!(y.len(), dy.len(), "{what}: output length");
        y.iter().zip(dy).map(|(&a, &b)| a as f64 * b as f64).sum()
    };
    for &i in probes {
        assert!(i < x.len(), "{what}: probe {i} out of range");
        let mut xp = x.to_vec();
        xp[i] += eps;
        let mut xm = x.to_vec();
        xm[i] -= eps;
        let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
        let an = analytic[i] as f64;
        assert!(
            (fd - an).abs() <= tol * (1.0 + an.abs()),
            "{what}: grad[{i}]: fd={fd} analytic={an} (eps={eps}, tol={tol})"
        );
    }
}

/// Deterministic spread of `count` probe indices over `0..n` (co-prime
/// stride so probes hit many rows/columns, not just a prefix).
pub fn probe_indices(n: usize, count: usize) -> Vec<usize> {
    assert!(n > 0);
    (0..count.min(n)).map(|k| (k * 7919 + 1) % n).collect()
}

/// Case-level generator handed to each property execution.
pub struct Gen {
    /// Per-case RNG, already seeded.
    pub rng: Rng,
    /// The case seed (printed on failure for replay).
    pub seed: u64,
}

impl Gen {
    /// Standard-normal f32.
    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Finite f32 spanning many binades (log-uniform magnitude, signed),
    /// occasionally exactly zero — the adversarial quantizer input.
    pub fn f32_wide(&mut self) -> f32 {
        match self.rng.below(16) {
            0 => 0.0,
            1 => self.rng.log_uniform_signed(-20.0, -6.0), // deep subnormal region
            2 => self.rng.log_uniform_signed(6.0, 12.0),   // near/above fp8 max
            _ => self.rng.log_uniform_signed(-12.0, 9.0),
        }
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Vector of `n` values from `f`.
    pub fn vec_of(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> f32) -> Vec<f32> {
        (0..n).map(|_| f(self)).collect()
    }
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Run `f` against `cases` random cases (scaled by `PROP_CASES`, overridden
/// to a single case by `PROP_SEED`). Panics with the case seed on failure.
pub fn props(name: &str, cases: usize, f: impl Fn(&mut Gen)) {
    if let Some(seed) = env_u64("PROP_SEED") {
        let mut g = Gen { rng: Rng::seed_from(seed), seed };
        f(&mut g);
        return;
    }
    let cases = env_u64("PROP_CASES").map(|c| c as usize).unwrap_or(cases);
    // Derive per-case seeds from the property name so adding properties
    // does not perturb existing ones.
    let name_hash = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
    });
    for case in 0..cases {
        let seed = name_hash.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::seed_from(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        props("abs is non-negative", 64, |g| {
            let x = g.f32_wide();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn failure_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            props("always fails", 4, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gradcheck_accepts_correct_gradient() {
        // L = Σ (x²) ⊙ dy → ∂L/∂xᵢ = 2·xᵢ·dyᵢ
        let x: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.25).collect();
        let dy: Vec<f32> = (0..16).map(|i| 1.0 + (i as f32) * 0.1).collect();
        let analytic: Vec<f32> = x.iter().zip(&dy).map(|(&a, &b)| 2.0 * a * b).collect();
        gradcheck(
            "quadratic",
            |xs| xs.iter().map(|&v| v * v).collect(),
            &x,
            &dy,
            &analytic,
            1e-3,
            1e-2,
            &probe_indices(16, 8),
        );
    }

    #[test]
    fn gradcheck_rejects_wrong_gradient() {
        let x = vec![1.0f32; 4];
        let dy = vec![1.0f32; 4];
        let wrong = vec![5.0f32; 4]; // true gradient is 2.0
        let r = std::panic::catch_unwind(|| {
            gradcheck(
                "bad",
                |xs| xs.iter().map(|&v| v * v).collect(),
                &x,
                &dy,
                &wrong,
                1e-3,
                1e-2,
                &[0],
            );
        });
        assert!(r.is_err());
    }

    #[test]
    fn probe_indices_in_range_and_distinct_enough() {
        let ps = probe_indices(100, 10);
        assert_eq!(ps.len(), 10);
        assert!(ps.iter().all(|&i| i < 100));
        let set: std::collections::BTreeSet<usize> = ps.iter().copied().collect();
        assert!(set.len() >= 9, "probes should mostly be distinct: {ps:?}");
    }

    #[test]
    fn wide_generator_hits_zero_and_extremes() {
        let mut g = Gen { rng: Rng::seed_from(123), seed: 123 };
        let xs: Vec<f32> = (0..4096).map(|_| g.f32_wide()).collect();
        assert!(xs.iter().any(|&x| x == 0.0));
        assert!(xs.iter().any(|&x| x.abs() > 448.0));
        assert!(xs.iter().any(|&x| x != 0.0 && x.abs() < 2.0_f32.powi(-9)));
    }
}
