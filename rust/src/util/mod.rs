//! Small self-contained utilities that substitute for crates unavailable in
//! this offline image (clap, criterion, proptest, serde, rand).
//!
//! Each submodule is deliberately tiny and fully tested; see DESIGN.md §3
//! ("Dependency constraints") for the substitution rationale.

pub mod bench;
pub mod cli;
pub mod json;
pub mod mat;
pub mod prop;
pub mod rng;
