//! Minimal JSON emitter (substitute for `serde_json`, not vendored here).
//!
//! Only what the metrics/experiment writers need: objects, arrays, strings,
//! numbers, bools. Emission only — the crate never needs to *parse* JSON
//! (configs are typed Rust; artifacts are HLO text).

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (integers render without a fraction).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (insertion-ordered key/value pairs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty JSON object; build it up with [`Json::set`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kv) => kv.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig1")
            .set("speedup", 2.5f64)
            .set("shapes", vec![128usize, 256, 512])
            .set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig1","speedup":2.5,"shapes":[128,256,512],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(42.5).render(), "42.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
