//! Minimal JSON emitter + parser (substitute for `serde_json`, not
//! vendored here).
//!
//! Only what the metrics/experiment writers and the trace tooling need:
//! objects, arrays, strings, numbers, bools. Emission serves the
//! `runs/*.json` writers; the parser exists for the observability loop —
//! `trace validate` and `calibrate` read back the documents this module
//! emitted (round-trip pinned by tests), nothing else.
//!
//! Every `runs/` document starts from [`Json::run_doc`], which stamps the
//! unified [`RUN_SCHEMA_VERSION`] and a `kind` tag — the one schema header
//! all four CLI writers (`epshard`, `bwd`, `train`, `serve`) and the trace
//! exporter share; `trace validate` rejects unknown versions.

use std::fmt::Write as _;

/// Version of the unified `runs/*.json` + trace-file schema. Bump when a
/// document's top-level layout changes incompatibly; `trace validate`
/// rejects files whose `schema_version` differs from the binary's.
pub const RUN_SCHEMA_VERSION: u64 = 1;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (integers render without a fraction).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (insertion-ordered key/value pairs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty JSON object; build it up with [`Json::set`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// The common header of every `runs/` document: an object pre-set
    /// with `schema_version` ([`RUN_SCHEMA_VERSION`]) and the document
    /// `kind` (`"epshard"`, `"bwd"`, `"train"`, `"serve"`, `"trace"`, …).
    pub fn run_doc(kind: &str) -> Json {
        Json::obj().set("schema_version", RUN_SCHEMA_VERSION).set("kind", kind)
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kv) => kv.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    // --- read side (trace validate / calibrate) ------------------------

    /// Parse a JSON document. Accepts exactly what [`Json::render`] emits
    /// plus standard whitespace/escapes; rejects trailing garbage.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a whole number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 1.9e19 => Some(*n as u64),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Key/value slice, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Recursive-descent parser over the raw bytes (ASCII structure; string
/// contents pass through as UTF-8).
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number span");
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".to_string());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".to_string());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => return Err(format!("bad escape at byte {}", self.i - 1)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy the full sequence through
                    let start = self.i - 1;
                    while self.peek().is_some_and(|c| c & 0xC0 == 0x80) {
                        self.i += 1;
                    }
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| format!("invalid UTF-8 at byte {start}"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        let hex4 = |p: &mut Parser| -> Result<u32, String> {
            if p.i + 4 > p.b.len() {
                return Err("truncated \\u escape".to_string());
            }
            let s = std::str::from_utf8(&p.b[p.i..p.i + 4])
                .map_err(|_| "bad \\u escape".to_string())?;
            let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
            p.i += 4;
            Ok(v)
        };
        let hi = hex4(self)?;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair: the low half must follow as \uXXXX
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = hex4(self)?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err("unpaired high surrogate".to_string());
                }
                let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(c).ok_or_else(|| "bad surrogate pair".to_string());
            }
            return Err("unpaired high surrogate".to_string());
        }
        char::from_u32(hi).ok_or_else(|| "bad \\u escape".to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig1")
            .set("speedup", 2.5f64)
            .set("shapes", vec![128usize, 256, 512])
            .set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig1","speedup":2.5,"shapes":[128,256,512],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(42.5).render(), "42.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn run_doc_carries_the_schema_header() {
        let j = Json::run_doc("epshard").set("ranks", 4usize);
        assert_eq!(j.get("schema_version").and_then(Json::as_u64), Some(RUN_SCHEMA_VERSION));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("epshard"));
        assert_eq!(j.get("ranks").and_then(Json::as_u64), Some(4));
    }

    #[test]
    fn parse_round_trips_render() {
        let j = Json::obj()
            .set("name", "trace \"x\"\n")
            .set("pi", 3.25f64)
            .set("neg", -17i64)
            .set("big", 1.5e300f64)
            .set("none", Json::Null)
            .set("flags", vec![true, false])
            .set("nested", Json::obj().set("k", vec![1usize, 2, 3]));
        let back = Json::parse(&j.render()).expect("round-trip parse");
        assert_eq!(back, j);
        // and the re-render is byte-identical (stable key order)
        assert_eq!(back.render(), j.render());
    }

    #[test]
    fn parse_accepts_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , \"\\u0041\\t\" ] ,\n \"b\" : null } ").unwrap();
        assert_eq!(j.get("a").and_then(Json::as_arr).map(|x| x.len()), Some(2));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_str(), Some("A\t"));
        assert_eq!(j.get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn accessors_are_type_strict() {
        let j = Json::parse(r#"{"n":3,"f":3.5,"s":"x","b":true}"#).unwrap();
        assert_eq!(j.get("n").and_then(Json::as_u64), Some(3));
        assert_eq!(j.get("f").and_then(Json::as_u64), None, "fractional is not u64");
        assert_eq!(j.get("f").and_then(Json::as_f64), Some(3.5));
        assert_eq!(j.get("s").and_then(Json::as_str), Some("x"));
        assert_eq!(j.get("b").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("n"), None);
    }
}
