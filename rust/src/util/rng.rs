//! Deterministic PRNG (xoshiro256**) — substitute for the `rand` crate.
//!
//! Used by tests, the property harness, synthetic data generation and the
//! cluster simulator. Determinism matters: every experiment in
//! EXPERIMENTS.md is reproducible from a printed seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (the reference seeding procedure).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Snapshot the raw 256-bit state (checkpointing). Restoring with
    /// [`Rng::from_state`] resumes the stream bitwise.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an `Rng` mid-stream from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa-ish bits for an exact dyadic uniform.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Log-uniform magnitude with random sign — the adversarial input for
    /// quantization tests (spans many binades, exercising scale diversity).
    pub fn log_uniform_signed(&mut self, log2_lo: f32, log2_hi: f32) -> f32 {
        let m = self.range_f32(log2_lo, log2_hi).exp2();
        if self.next_u64() & 1 == 0 {
            m
        } else {
            -m
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::seed_from(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seed_from(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
