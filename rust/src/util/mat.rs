//! Minimal dense row-major `f32` matrix used throughout the native (L3)
//! kernels and the test oracles. Deliberately tiny: the heavy lifting on
//! the request path happens either in the FP8 domain (`crate::fp8`) or
//! inside AOT-compiled XLA executables.

use crate::util::rng::Rng;

/// Dense row-major `f32` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major elements, `rows * cols` of them.
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap `data` (row-major, length `rows * cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build element `(i, j)` from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Gaussian entries, `std` standard deviation.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Mat { rows, cols, data }
    }

    /// Log-uniform magnitudes spanning `[2^lo, 2^hi)` with random signs —
    /// the adversarial distribution for quantization tests.
    pub fn rand_log_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.log_uniform_signed(lo, hi)).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    /// Element `(i, j)`.
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    /// Mutable element `(i, j)`.
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `self @ other`, f32 accumulate.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for l in 0..k {
                let a = self.data[i * k + l];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[l * n..(l + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Relative Frobenius error `|self − other|_F / |other|_F`.
    pub fn rel_err(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let num: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt();
        let den = other.frobenius().max(1e-30);
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let a = Mat::randn(7, 13, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_indices() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f32);
        let t = a.transpose();
        for i in 0..3 {
            for j in 0..5 {
                assert_eq!(a.at(i, j), t.at(j, i));
            }
        }
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        assert_eq!(a.rel_err(&a), 0.0);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::randn(5, 5, 1.0, &mut rng);
        let id = Mat::from_fn(5, 5, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = a.matmul(&id);
        assert_eq!(a, b);
    }
}
