//! Miniature benchmark harness (substitute for `criterion`, which is not
//! vendored in this image).
//!
//! Provides warmup, calibrated iteration counts, and robust statistics
//! (median / p10 / p90), plus table-formatted reporting used by the
//! per-figure/table bench binaries (`rust/benches/*.rs`, `harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label (table row).
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// 10th-percentile iteration time.
    pub p10: Duration,
    /// 90th-percentile iteration time.
    pub p90: Duration,
    /// Optional throughput denominator (bytes processed per iteration).
    pub bytes: Option<u64>,
}

impl BenchStats {
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    /// Throughput in GiB/s when a byte count was declared.
    pub fn gib_per_s(&self) -> Option<f64> {
        self.bytes
            .map(|b| b as f64 / self.median.as_secs_f64() / (1u64 << 30) as f64)
    }
}

/// Benchmark runner. `quick()` (or env `BENCH_QUICK=1`) shrinks budgets so
/// `cargo test`-adjacent smoke runs stay fast.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Warmup duration before measurement starts.
    pub warmup: Duration,
    /// Measurement time budget.
    pub measure: Duration,
    /// Minimum iterations regardless of budget.
    pub min_iters: usize,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        if std::env::var("BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Bencher {
                warmup: Duration::from_millis(200),
                measure: Duration::from_millis(900),
                min_iters: 5,
                max_iters: 10_000,
            }
        }
    }
}

/// Shared bench-binary entrypoint: parse the CLI (`--threads N` routes
/// into the [`crate::exec`] layer, 0 = auto; `--quick` shrinks budgets),
/// report the effective worker count, and hand back the remaining args.
///
/// `default_threads` is what `--threads` falls back to. The paper-figure
/// benches pass **1**: their unfused baselines are serial kernels, so the
/// fused side must run serial too or the printed SPEEDUP conflates fusion
/// with multithreading. The scaling section of `perf_kernels` passes 0
/// (auto) — comparing worker counts is its whole point.
pub fn bencher_from_cli(default_threads: usize) -> (Bencher, crate::util::cli::Args) {
    let args = crate::util::cli::Args::from_env();
    crate::exec::set_threads(args.usize_or("threads", default_threads));
    let b = if args.flag("quick") { Bencher::quick() } else { Bencher::default() };
    println!("threads: {} (override with --threads N)", crate::exec::threads());
    (b, args)
}

impl Bencher {
    /// Shrunk budgets for smoke runs (`BENCH_QUICK=1`).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            min_iters: 3,
            max_iters: 200,
        }
    }

    /// Measure `f`, returning robust stats. `f` must do the full unit of
    /// work each call; use `std::hint::black_box` on inputs/outputs.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchStats {
        // Warmup + single-shot estimate.
        let t0 = Instant::now();
        let mut warm_iters = 0usize;
        while t0.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = t0.elapsed() / warm_iters.max(1) as u32;
        let target = self
            .measure
            .as_nanos()
            .checked_div(per_iter.as_nanos().max(1))
            .unwrap_or(self.min_iters as u128) as usize;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        BenchStats {
            name: name.to_string(),
            iters,
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            bytes: None,
        }
    }

    /// Like [`run`](Self::run) with a bytes-per-iteration annotation for
    /// throughput reporting.
    pub fn run_bytes(&self, name: &str, bytes: u64, f: impl FnMut()) -> BenchStats {
        let mut s = self.run(name, f);
        s.bytes = Some(bytes);
        s
    }
}

/// Print a uniform results table; used by every bench binary so outputs in
/// `bench_output.txt` are machine-greppable (`ROW <bench> ...`).
pub fn print_table(title: &str, rows: &[BenchStats]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "case", "median_ms", "p10_ms", "p90_ms", "GiB/s"
    );
    for r in rows {
        println!(
            "ROW {:<40} {:>10.4} {:>10.4} {:>10.4} {:>10}",
            r.name,
            r.median_ms(),
            r.p10.as_secs_f64() * 1e3,
            r.p90.as_secs_f64() * 1e3,
            r.gib_per_s().map(|g| format!("{g:.2}")).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Speedup line used by the figure benches ("who wins, by what factor").
pub fn print_speedup(label: &str, baseline: &BenchStats, ours: &BenchStats) {
    let s = baseline.median.as_secs_f64() / ours.median.as_secs_f64();
    println!("SPEEDUP {label}: {s:.2}x  ({} -> {})", baseline.name, ours.name);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(s.iters >= 3);
        assert!(s.median > Duration::ZERO);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bencher::quick();
        let buf = vec![1u8; 1 << 16];
        let s = b.run_bytes("memsum", buf.len() as u64, || {
            std::hint::black_box(buf.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(s.gib_per_s().unwrap() > 0.0);
    }
}
