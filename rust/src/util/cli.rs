//! Minimal command-line parsing (substitute for `clap`, not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! typed getters with defaults. Each binary prints its own usage text.

use std::collections::BTreeMap;

/// Parsed argument bag.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / bare `--flag` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `std::env::args` callers
    /// should use [`Args::from_env`].
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value =
                        matches!(it.peek(), Some(n) if !n.starts_with("--"));
                    if takes_value {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(rest.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// True when `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// True when the user asked for usage text: a `--help` flag anywhere
    /// (even when the parser attached a value to it, as in
    /// `--help train`), or `-h`/`help` in any positional slot
    /// (single-dash args parse as positionals, so `train -h` lands here).
    pub fn help_requested(&self) -> bool {
        self.flags.contains_key("help")
            || self.positional.iter().any(|p| p == "-h" || p == "help")
    }

    /// Value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Value of `--key`, or `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `--key` parsed as `usize`, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// `--key` parsed as `u64`, or `default`.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// `--key` parsed as `f64`, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(sv(&["train", "--steps", "100", "--lr=0.01", "--verbose"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.01);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(sv(&[]));
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.get_or("mode", "bf16"), "bf16");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(sv(&["--fast", "--steps", "3"]));
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("steps", 0), 3);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        let a = Args::parse(sv(&["--steps", "abc"]));
        a.usize_or("steps", 0);
    }

    #[test]
    fn help_detection() {
        assert!(Args::parse(sv(&["--help"])).help_requested());
        assert!(Args::parse(sv(&["-h"])).help_requested());
        assert!(Args::parse(sv(&["help"])).help_requested());
        // flag anywhere, even when the parser eats a value or it trails
        assert!(Args::parse(sv(&["--help", "train"])).help_requested());
        assert!(Args::parse(sv(&["train", "-h"])).help_requested());
        assert!(Args::parse(sv(&["train", "--help"])).help_requested());
        assert!(!Args::parse(sv(&["train", "--steps", "3"])).help_requested());
        assert!(!Args::parse(sv(&[])).help_requested());
    }
}
