//! Minimal command-line parsing (substitute for `clap`, not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! typed getters with defaults. Each binary prints its own usage text.

use std::collections::BTreeMap;

/// Parsed argument bag.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / bare `--flag` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `std::env::args` callers
    /// should use [`Args::from_env`].
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value =
                        matches!(it.peek(), Some(n) if !n.starts_with("--"));
                    if takes_value {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(rest.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// True when `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// True when the user asked for usage text: a `--help` flag anywhere
    /// (even when the parser attached a value to it, as in
    /// `--help train`), or `-h`/`help` in any positional slot
    /// (single-dash args parse as positionals, so `train -h` lands here).
    pub fn help_requested(&self) -> bool {
        self.flags.contains_key("help")
            || self.positional.iter().any(|p| p == "-h" || p == "help")
    }

    /// Value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Value of `--key`, or `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// `--key` parsed as `usize`, or `default`; `Err` on a malformed
    /// value (the CLI maps it to the stderr + exit-2 contract).
    pub fn try_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        self.get(key).map_or(Ok(default), |v| {
            v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}"))
        })
    }

    /// `--key` parsed as `u64`, or `default`; `Err` on a malformed value.
    pub fn try_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        self.get(key).map_or(Ok(default), |v| {
            v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}"))
        })
    }

    /// `--key` parsed as a finite `f64`, or `default`; `Err` on a
    /// malformed or non-finite value (`NaN` capacity factors would
    /// otherwise sail through every comparison).
    pub fn try_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        self.get(key).map_or(Ok(default), |v| {
            v.parse::<f64>()
                .ok()
                .filter(|x| x.is_finite())
                .ok_or_else(|| format!("--{key} expects a finite number, got {v:?}"))
        })
    }

    /// `--key` parsed as `usize`, or `default`. Panics on a malformed
    /// value — test/tool convenience; CLI paths use [`Args::try_usize`].
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.try_usize(key, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// `--key` parsed as `u64`, or `default`. Panics on a malformed
    /// value — test/tool convenience; CLI paths use [`Args::try_u64`].
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.try_u64(key, default).unwrap_or_else(|e| panic!("{e}"))
    }

    /// `--key` parsed as `f64`, or `default`. Panics on a malformed
    /// value — test/tool convenience; CLI paths use [`Args::try_f64`].
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.try_f64(key, default).unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(sv(&["train", "--steps", "100", "--lr=0.01", "--verbose"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.01);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(sv(&[]));
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.get_or("mode", "bf16"), "bf16");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(sv(&["--fast", "--steps", "3"]));
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("steps", 0), 3);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        let a = Args::parse(sv(&["--steps", "abc"]));
        a.usize_or("steps", 0);
    }

    #[test]
    fn try_getters_surface_malformed_values_as_errors() {
        let a = Args::parse(sv(&["--steps", "abc", "--seed", "1e3", "--cf", "nan"]));
        assert!(a.try_usize("steps", 0).unwrap_err().contains("--steps"));
        assert!(a.try_u64("seed", 0).unwrap_err().contains("--seed"));
        assert!(a.try_f64("cf", 1.0).unwrap_err().contains("--cf"), "NaN must be rejected");
        let ok = Args::parse(sv(&["--steps", "12", "--cf", "0.5"]));
        assert_eq!(ok.try_usize("steps", 0), Ok(12));
        assert_eq!(ok.try_f64("cf", 1.0), Ok(0.5));
        assert_eq!(ok.try_u64("absent", 9), Ok(9), "absent flag falls back to the default");
    }

    #[test]
    fn negative_integers_are_malformed_not_wrapped() {
        let a = Args::parse(sv(&["--tokens=-5", "--ranks=-1"]));
        assert!(a.try_usize("ranks", 1).is_err(), "-1 must not wrap to usize::MAX");
        assert!(a.try_u64("tokens", 1).is_err());
    }

    #[test]
    fn help_detection() {
        assert!(Args::parse(sv(&["--help"])).help_requested());
        assert!(Args::parse(sv(&["-h"])).help_requested());
        assert!(Args::parse(sv(&["help"])).help_requested());
        // flag anywhere, even when the parser eats a value or it trails
        assert!(Args::parse(sv(&["--help", "train"])).help_requested());
        assert!(Args::parse(sv(&["train", "-h"])).help_requested());
        assert!(Args::parse(sv(&["train", "--help"])).help_requested());
        assert!(!Args::parse(sv(&["train", "--steps", "3"])).help_requested());
        assert!(!Args::parse(sv(&[])).help_requested());
    }
}
