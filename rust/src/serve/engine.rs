//! EP-sharded serving loop over the MoE stage APIs.
//!
//! Each flush tick from the micro-batcher becomes one forward through
//! route → per-rank [`dispatch`] → [`expert_ffn`] → [`combine`], sharded
//! over `ranks` contiguous expert ranges exactly like
//! [`crate::cluster::ep_exec`] (which can also drive the tick when the
//! PR 7 overlap pipeline is requested). The engine owns the per-slot
//! dispatch plans, so capacity drops are accounted **exactly**: a
//! (token, slot) pair is dropped iff its plan entry never materializes,
//! and `Σ_rank real_rows + dropped_slots = tokens · top_k` per tick.
//!
//! **Bit-identity contract** (the serving extension of the repo-wide
//! story): a token's served output is bitwise identical to one-shot
//! [`moe_forward`] over any token set containing it, provided no slot of
//! the token was capacity-dropped. This holds because every per-token
//! path is batch-independent — routing (row-wise softmax + top-k), the
//! Fp8Flow entry quantization (row-wise tiles), the FP8 GEMMs (fixed
//! per-element k-tile accumulation per output row), and the gated
//! combine (per-token) — and per-rank combine partials sum to the
//! single-rank combine bit-for-bit (`moe::layer` pins that).
//! `tests/prop_serve.rs` pins the end-to-end property; the `serve` CLI
//! gates on it every run.
//!
//! **Degraded mode** (fault-injected runs): an engine built with
//! [`ServeEngine::with_faults`] survives rank loss. A crashed rank's
//! in-flight dispatch is lost for that tick (its slots land in the
//! `failed_rank_drops` ledger term), and from the next tick the
//! [`FailoverPolicy`] decides: `Reroute` re-partitions the full expert
//! range over the surviving ranks (every expert stays served, numerics
//! unchanged — each (token, slot) pair still has exactly one nonzero
//! combine contribution, so the partial-sum regrouping is exact), while
//! `Drop` keeps the static ownership and drops the dead ranks' expert
//! slots every tick. Either way the tick ledger stays exact:
//! `Σ_rank real_rows + dropped_slots + failed_rank_drops = tokens·top_k`.

use std::ops::Range;
use std::time::Instant;

use crate::cluster::ep_exec::{ep_forward, EpConfig};
use crate::cluster::fault::{FaultPlan, FaultStats};
use crate::cluster::rank::WireBuf;
use crate::exec::{self, Partition};
use crate::fp8::tile::quantize_rowwise;
use crate::fp8::{ue8m0, Fp8Format, ScaleMode};
use crate::moe::layer::{combine, dispatch, expert_ffn, DispatchSource, PreparedWeights, Recipe};
use crate::moe::permute::permute_pad_plan;
use crate::moe::router::route;
use crate::obs::{self, Counter};
use crate::util::mat::Mat;
use crate::util::rng::Rng;

use super::batch::{effective_capacity, schedule, DropPolicy, SloPolicy, Tick};
use super::gen::Request;

/// Fixed seeded token-id → activation-row embedding. One table per
/// engine, deterministic in the seed, so a token id always routes the
/// same way — skewed id frequencies in the corpus become skewed expert
/// load.
pub struct TokenEmbed {
    table: Mat, // [vocab, d_model]
}

impl TokenEmbed {
    /// Build the `[vocab, d_model]` table from `seed`.
    pub fn new(vocab: usize, d_model: usize, seed: u64) -> TokenEmbed {
        let mut rng = Rng::seed_from(seed ^ 0xE3BED);
        TokenEmbed { table: Mat::randn(vocab, d_model, 0.5, &mut rng) }
    }

    /// Gather `ids` into an activation matrix `[ids.len(), d_model]`.
    pub fn embed(&self, ids: &[i32]) -> Mat {
        let d = self.table.cols;
        let mut x = Mat::zeros(ids.len(), d);
        for (i, &id) in ids.iter().enumerate() {
            let id = id as usize % self.table.rows;
            x.data[i * d..(i + 1) * d].copy_from_slice(self.table.row(id));
        }
        x
    }
}

/// Serving-loop configuration (the knobs of one engine run).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Number of simulated EP ranks sharding the expert range.
    pub ranks: usize,
    /// Router top-k.
    pub top_k: usize,
    /// Capacity factor under [`DropPolicy::Capacity`].
    pub capacity_factor: f64,
    /// Token-drop policy.
    pub drop_policy: DropPolicy,
    /// Worker budget per stage call (0 = the global [`exec::threads`]).
    pub threads: usize,
    /// Per-rank pipeline chunks (> 1 enables the PR 7 overlap pipeline).
    pub chunks: usize,
    /// Run the tick through the overlapped EP pipeline
    /// ([`EpConfig::with_pipeline`]) instead of the serialized stage loop.
    pub overlap: bool,
}

impl ServeConfig {
    /// True when the tick forward should run the PR 7 overlap pipeline.
    pub fn pipelined(&self) -> bool {
        self.overlap || self.chunks > 1
    }
}

/// What the engine does with a failed rank's expert range from the tick
/// after the failure onward (the failure tick itself always loses its
/// in-flight dispatch to `failed_rank_drops`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailoverPolicy {
    /// Re-partition the **full** expert range over the surviving ranks:
    /// every expert stays served and tick outputs stay bit-identical to
    /// the healthy engine (the regrouped combine partials are exact —
    /// each (token, slot) pair has one nonzero contribution).
    Reroute,
    /// Keep the static ownership; the dead ranks' expert slots are
    /// dropped every tick through the `failed_rank_drops` ledger term.
    Drop,
}

/// Result of one flush-tick forward.
pub struct TickResult {
    /// Batch output `[tokens, d]` (rows of dropped slots miss that
    /// expert's contribution).
    pub y: Mat,
    /// Per-row flag: true iff the token survived in **every** top-k slot.
    pub fully_served: Vec<bool>,
    /// Dropped (token, slot) pairs in this tick.
    pub dropped_slots: usize,
    /// (token, slot) pairs lost to failed ranks this tick (crash-tick
    /// in-flight loss, plus — under [`FailoverPolicy::Drop`] — the dead
    /// ranks' standing expert slots). Disjoint from `dropped_slots`, so
    /// `Σ rank_rows + dropped_slots + failed_rank_drops = tokens·top_k`.
    pub failed_rank_drops: usize,
    /// True iff the tick ran with at least one failed rank.
    pub degraded: bool,
    /// Real (non-pad) dispatched rows per rank, summed over slots.
    pub rank_rows: Vec<usize>,
    /// Per-rank expert-FFN seconds, summed over slots.
    pub rank_expert_s: Vec<f64>,
    /// Wall-clock of the whole tick forward (route + quant + stages).
    pub service_s: f64,
    /// Effective per-expert per-slot capacity used.
    pub capacity: usize,
}

/// The EP-sharded serving engine: prepared weights + embedding + config.
pub struct ServeEngine {
    /// Per-recipe prepared weights the expert stages run on.
    pub weights: PreparedWeights,
    /// The fixed token embedding.
    pub embed: TokenEmbed,
    /// Engine knobs.
    pub cfg: ServeConfig,
    faults: FaultPlan,
    failover: FailoverPolicy,
}

impl ServeEngine {
    /// Build an engine. Panics unless `1 ≤ ranks ≤ E` and
    /// `1 ≤ top_k ≤ E` (the stage-API invariants).
    pub fn new(weights: PreparedWeights, embed: TokenEmbed, cfg: ServeConfig) -> ServeEngine {
        let e = weights.raw.n_experts();
        assert!(cfg.ranks >= 1 && e >= cfg.ranks, "need 1 <= ranks <= E");
        assert!(cfg.top_k >= 1 && cfg.top_k <= e, "need 1 <= top_k <= E");
        assert!(cfg.chunks >= 1, "need at least one pipeline chunk");
        ServeEngine { weights, embed, cfg, faults: FaultPlan::none(), failover: FailoverPolicy::Reroute }
    }

    /// Arm the engine with a fault schedule and a failover policy. An
    /// armed engine always runs the serialized stage loop (the chaos
    /// coordinate system is the serve tick, which the overlap pipeline's
    /// chunk lanes would blur), so the pipelined flags are ignored while
    /// faults are scheduled.
    pub fn with_faults(mut self, faults: FaultPlan, failover: FailoverPolicy) -> ServeEngine {
        self.faults = faults;
        self.failover = failover;
        self
    }

    /// Recovery totals of the armed fault plan (all zero when unarmed).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.stats()
    }

    fn threads(&self) -> usize {
        if self.cfg.threads == 0 {
            exec::threads()
        } else {
            self.cfg.threads
        }
    }

    /// Per-expert per-slot capacity for a batch of `t` tokens.
    pub fn capacity_for(&self, t: usize) -> usize {
        effective_capacity(
            self.cfg.drop_policy,
            self.cfg.capacity_factor,
            t,
            self.cfg.top_k,
            self.weights.raw.n_experts(),
        )
    }

    /// Run one micro-batch through the EP-sharded forward. `x` may have
    /// zero rows (an empty flush tick): the result is empty, no panic —
    /// the zero-row edge the empty-batch property tests pin.
    pub fn forward_batch(&self, x: &Mat) -> TickResult {
        self.forward_batch_at(0, x)
    }

    /// [`ServeEngine::forward_batch`] at an explicit serve tick index —
    /// the coordinate an armed [`FaultPlan`] matches against. Crashes
    /// scheduled at `tick` are consumed first (their in-flight dispatch
    /// lands in `failed_rank_drops`), wire faults are injected into the
    /// tick's checksummed wire image and recovered (counters only — the
    /// served bytes are the recovered, pristine ones), and the standing
    /// failed-rank set drives expert ownership per the
    /// [`FailoverPolicy`].
    pub fn forward_batch_at(&self, tick: usize, x: &Mat) -> TickResult {
        let t0 = Instant::now();
        let t = x.rows;
        let e = self.weights.raw.n_experts();
        let (ranks, top_k) = (self.cfg.ranks, self.cfg.top_k);
        let threads = self.threads();
        let cap = self.capacity_for(t);
        let shard = Partition::even(e, ranks);

        // fault bookkeeping first: ranks crashing at this tick lose
        // their in-flight dispatch below, and the standing failed set
        // decides this tick's expert ownership
        let newly = self.faults.crashed_at(tick as u64);
        let failed: Vec<bool> = (0..ranks).map(|r| self.faults.is_failed(r)).collect();
        let degraded = failed.iter().any(|&f| f);
        if self.faults.armed() && t > 0 {
            self.faults.deliver_tick(tick as u64, &self.tick_wire_image(x));
        }

        let sr = obs::enabled()
            .then(|| obs::span(format!("route t{t}"), obs::SpanMeta::stage("route")));
        let routing = route(x, &self.weights.raw.router, top_k);
        drop(sr);
        let mut plans: Vec<Vec<i64>> = (0..top_k)
            .map(|kk| {
                let expert_of: Vec<usize> = routing.experts.iter().map(|ex| ex[kk]).collect();
                permute_pad_plan(&expert_of, e, cap)
            })
            .collect();

        // void the plan entries of expert segments that lost their
        // server this tick, remembering which (token, slot) pairs they
        // were — those are failed-rank drops, not capacity drops
        let masked_ex = self.masked_expert_ids(&shard, &failed, &newly);
        let mut masked: Vec<Vec<bool>> = vec![vec![false; t]; top_k];
        for (kk, plan) in plans.iter_mut().enumerate() {
            for &ex in &masked_ex {
                for p in &mut plan[ex * cap..(ex + 1) * cap] {
                    if *p >= 0 {
                        masked[kk][*p as usize] = true;
                        *p = -1;
                    }
                }
            }
        }
        let owners = self.owner_segments(e, &failed);

        // exact drop accounting straight off the (masked) plans
        let mut fully_served = vec![true; t];
        let mut dropped_slots = 0usize;
        let mut failed_rank_drops = 0usize;
        let mut rank_rows = vec![0usize; ranks];
        for (kk, plan) in plans.iter().enumerate() {
            let mut present = vec![false; t];
            for (r, er) in &owners {
                for &p in &plan[er.start * cap..er.end * cap] {
                    if p >= 0 {
                        present[p as usize] = true;
                        rank_rows[*r] += 1;
                    }
                }
            }
            for (tt, &ok) in present.iter().enumerate() {
                if !ok {
                    fully_served[tt] = false;
                    if masked[kk][tt] {
                        failed_rank_drops += 1;
                    } else {
                        dropped_slots += 1;
                    }
                }
            }
        }

        let (y, rank_expert_s) = if self.cfg.pipelined() && t >= 1 && !self.faults.armed() {
            // the PR 7 double-buffered pipeline; bit-identical to the
            // serialized stage loop below (prop_ep_shard pins it)
            let cfg = EpConfig::serial(ranks, top_k, cap, self.cfg.threads)
                .with_pipeline(self.cfg.chunks, self.cfg.overlap);
            let out = ep_forward(x, &self.weights, &cfg);
            (out.y, out.rank_expert_s)
        } else {
            self.staged_forward(x, &routing.gates, &plans, cap, threads, &owners)
        };

        TickResult {
            y,
            fully_served,
            dropped_slots,
            failed_rank_drops,
            degraded,
            rank_rows,
            rank_expert_s,
            service_s: t0.elapsed().as_secs_f64(),
            capacity: cap,
        }
    }

    /// The tick's wire image for fault injection: the same byte classes
    /// the EP dispatch puts on the all-to-all — FP8 codes plus the UE8M0
    /// scale sidecar for Fp8Flow, the dense f32 image otherwise. Built
    /// on a copy, so detection and retry never touch the served tensors.
    fn tick_wire_image(&self, x: &Mat) -> WireBuf {
        if self.weights.recipe == Recipe::Fp8Flow {
            let xq = quantize_rowwise(x, Fp8Format::E4M3, ScaleMode::Po2);
            let sidecar = xq.sexp.iter().map(|&se| ue8m0::from_exponent(se)).collect();
            WireBuf::Fp8 { codes: xq.data, sidecar }
        } else {
            WireBuf::Dense(x.data.clone())
        }
    }

    /// Expert ids whose plan entries are voided this tick: under
    /// [`FailoverPolicy::Drop`] every failed rank's static segment, and
    /// under [`FailoverPolicy::Reroute`] only the ranks that crashed at
    /// this very tick — survivors pick their experts up from the next
    /// tick on.
    fn masked_expert_ids(&self, shard: &Partition, failed: &[bool], newly: &[usize]) -> Vec<usize> {
        let mut out = Vec::new();
        for (r, er) in shard.ranges().enumerate() {
            let hit = match self.failover {
                FailoverPolicy::Drop => failed[r],
                FailoverPolicy::Reroute => newly.contains(&r),
            };
            if hit {
                out.extend(er);
            }
        }
        out
    }

    /// This tick's expert ownership as `(rank, expert range)` pairs:
    /// the static even partition minus failed segments normally (and
    /// always under [`FailoverPolicy::Drop`]); under
    /// [`FailoverPolicy::Reroute`] with failures, the **full** expert
    /// range re-split evenly over the surviving ranks.
    fn owner_segments(&self, e: usize, failed: &[bool]) -> Vec<(usize, Range<usize>)> {
        let ranks = self.cfg.ranks;
        let live: Vec<usize> = (0..ranks).filter(|&r| !failed[r]).collect();
        if live.len() == ranks || self.failover == FailoverPolicy::Drop {
            return Partition::even(e, ranks)
                .ranges()
                .enumerate()
                .filter(|&(r, _)| !failed[r])
                .collect();
        }
        if live.is_empty() {
            return Vec::new();
        }
        Partition::even(e, live.len())
            .ranges()
            .enumerate()
            .map(|(i, er)| (live[i], er))
            .collect()
    }

    /// The serialized per-rank stage loop: for each top-k slot, dispatch /
    /// expert-FFN / combine each owner's expert range and sum the
    /// per-owner combine partials. Bitwise equal to the full-range
    /// combine for **any** ownership split, because each (token, slot)
    /// pair is dispatched to exactly one expert — every partial sum has
    /// at most one nonzero contribution per output element, so the
    /// regrouping is exact (that is what keeps rerouted degraded ticks
    /// bit-identical to healthy ones).
    fn staged_forward(
        &self,
        x: &Mat,
        gates: &[Vec<f32>],
        plans: &[Vec<i64>],
        cap: usize,
        threads: usize,
        owners: &[(usize, Range<usize>)],
    ) -> (Mat, Vec<f64>) {
        let t = x.rows;
        let ranks = self.cfg.ranks;
        let x_q = (self.weights.recipe == Recipe::Fp8Flow).then(|| {
            let _s = obs::enabled()
                .then(|| obs::span("entry quant".to_string(), obs::SpanMeta::stage("quant")));
            obs::count(Counter::CastsFwd, 1); // Fp8Flow's single forward cast
            quantize_rowwise(x, Fp8Format::E4M3, ScaleMode::Po2)
        });
        let mut y = Mat::zeros(t, x.cols);
        let mut rank_expert_s = vec![0.0f64; ranks];
        for (kk, plan) in plans.iter().enumerate() {
            let mut slot = Mat::zeros(t, x.cols);
            for (r, er) in owners {
                let (r, er) = (*r, er.clone());
                let src = match &x_q {
                    Some(xq) => DispatchSource::Fp8(xq),
                    None => DispatchSource::Dense(x),
                };
                let sd = obs::enabled().then(|| {
                    obs::span(
                        format!("dispatch r{r} k{kk}"),
                        obs::SpanMeta::stage("dispatch").rank(r as u32).step(kk),
                    )
                });
                let batch = dispatch(src, plan, er.clone(), cap, threads);
                drop(sd);
                let te = Instant::now();
                let sf = obs::enabled().then(|| {
                    obs::span(
                        format!("ffn r{r} k{kk}"),
                        obs::SpanMeta::stage("ffn").rank(r as u32).step(kk),
                    )
                });
                let yk = expert_ffn(&batch, &self.weights, threads);
                drop(sf);
                rank_expert_s[r] += te.elapsed().as_secs_f64();
                let sc = obs::enabled().then(|| {
                    obs::span(
                        format!("combine r{r} k{kk}"),
                        obs::SpanMeta::stage("combine").rank(r as u32).step(kk),
                    )
                });
                let part = combine(&yk, plan, er, cap, t, threads);
                drop(sc);
                for (acc, v) in slot.data.iter_mut().zip(&part.data) {
                    *acc += v;
                }
            }
            for tt in 0..t {
                let g = gates[tt][kk];
                for j in 0..x.cols {
                    y.data[tt * x.cols + j] += g * slot.data[tt * x.cols + j];
                }
            }
        }
        (y, rank_expert_s)
    }
}

/// Aggregate result of serving one request trace end to end.
pub struct ServeSummary {
    /// Requests served.
    pub requests: usize,
    /// Flush ticks executed.
    pub ticks: usize,
    /// Total prompt tokens through the engine.
    pub total_tokens: usize,
    /// Tokens that survived every top-k slot (bit-identical to one-shot).
    pub served_tokens: usize,
    /// Tokens that lost at least one slot to a capacity drop.
    pub degraded_tokens: usize,
    /// Dropped (token, slot) pairs, summed over ticks.
    pub dropped_slots: usize,
    /// (token, slot) pairs lost to failed ranks, summed over ticks (the
    /// degraded-mode ledger term; 0 on a healthy run).
    pub failed_rank_drops: usize,
    /// Ticks that ran with at least one failed rank (degraded mode).
    pub degraded_ticks: usize,
    /// Real dispatched rows per rank, summed over ticks and slots.
    pub rank_rows: Vec<usize>,
    /// Per-rank expert seconds, summed over ticks and slots.
    pub rank_expert_s: Vec<f64>,
    /// Throughput: `total_tokens / sim_elapsed_s`.
    pub tokens_per_s: f64,
    /// Median request latency (arrival → batch completion), seconds.
    pub p50_s: f64,
    /// 99th-percentile request latency, seconds.
    pub p99_s: f64,
    /// Simulated makespan: last batch completion on the virtual clock.
    pub sim_elapsed_s: f64,
    /// Measured compute seconds (sum of tick service times).
    pub busy_s: f64,
    /// Smallest / largest effective capacity across ticks.
    pub capacity_range: (usize, usize),
    /// Mean tokens per tick.
    pub mean_batch_tokens: f64,
    /// Engine outputs, one row per token in request order.
    pub y: Mat,
    /// Per-token fully-served flags, aligned with `y` rows.
    pub fully_served: Vec<bool>,
}

impl ServeSummary {
    /// Fraction of (token, slot) dispatch entries dropped.
    pub fn drop_frac(&self, top_k: usize) -> f64 {
        if self.total_tokens == 0 {
            return 0.0;
        }
        self.dropped_slots as f64 / (self.total_tokens * top_k) as f64
    }
}

/// Drive the full serving loop: schedule the trace under `slo`, run each
/// tick through `engine`, and merge latency/throughput/drop accounting.
///
/// Time model: batch **composition** is a pure function of the trace and
/// the SLO ([`schedule`]); the completion clock then replays the ticks
/// against measured service time — a tick starts at
/// `max(flush_s, engine_free)` and completes `service_s` later, so
/// queueing delay shows up in p50/p99 exactly when the engine falls
/// behind the offered load.
pub fn serve_trace(engine: &ServeEngine, requests: &[Request], slo: &SloPolicy) -> ServeSummary {
    let ticks: Vec<Tick> = schedule(requests, slo);
    let d = engine.embed.table.cols;
    let total_tokens: usize = requests.iter().map(Request::len).sum();
    let offsets: Vec<usize> = requests
        .iter()
        .scan(0usize, |acc, r| {
            let o = *acc;
            *acc += r.len();
            Some(o)
        })
        .collect();

    let mut y = Mat::zeros(total_tokens, d);
    let mut fully_served = vec![false; total_tokens];
    let mut rank_rows = vec![0usize; engine.cfg.ranks];
    let mut rank_expert_s = vec![0.0f64; engine.cfg.ranks];
    let mut dropped_slots = 0usize;
    let mut failed_rank_drops = 0usize;
    let mut degraded_ticks = 0usize;
    let mut latencies = Vec::with_capacity(requests.len());
    let mut engine_free = 0.0f64;
    let mut busy_s = 0.0f64;
    let (mut cap_min, mut cap_max) = (usize::MAX, 0usize);

    for (ti, tick) in ticks.iter().enumerate() {
        let st = obs::enabled()
            .then(|| obs::span(format!("tick {ti}"), obs::SpanMeta::stage("tick").step(ti)));
        let ids: Vec<i32> =
            tick.requests.iter().flat_map(|&i| requests[i].tokens.iter().copied()).collect();
        let x = engine.embed.embed(&ids);
        let res = engine.forward_batch_at(ti, &x);
        drop(st);
        if obs::enabled() {
            let served = res.fully_served.iter().filter(|&&s| s).count();
            obs::count(Counter::ServedTokens, served as u64);
            obs::count(Counter::DegradedTokens, (res.fully_served.len() - served) as u64);
            obs::count(Counter::DroppedSlots, res.dropped_slots as u64);
            obs::sample("tick_service_s", res.service_s);
            obs::sample("tick_tokens", x.rows as f64);
        }

        let start = engine_free.max(tick.flush_s);
        let done = start + res.service_s;
        engine_free = done;
        busy_s += res.service_s;
        for &i in &tick.requests {
            latencies.push(done - requests[i].arrival_s);
            if obs::enabled() {
                obs::sample("request_latency_s", done - requests[i].arrival_s);
            }
        }

        // scatter tick rows back to the global token stream
        let mut row = 0usize;
        for &i in &tick.requests {
            let o = offsets[i];
            for k in 0..requests[i].len() {
                y.data[(o + k) * d..(o + k + 1) * d]
                    .copy_from_slice(&res.y.data[(row + k) * d..(row + k + 1) * d]);
                fully_served[o + k] = res.fully_served[row + k];
            }
            row += requests[i].len();
        }

        dropped_slots += res.dropped_slots;
        failed_rank_drops += res.failed_rank_drops;
        degraded_ticks += usize::from(res.degraded);
        for (acc, v) in rank_rows.iter_mut().zip(&res.rank_rows) {
            *acc += v;
        }
        for (acc, v) in rank_expert_s.iter_mut().zip(&res.rank_expert_s) {
            *acc += v;
        }
        cap_min = cap_min.min(res.capacity);
        cap_max = cap_max.max(res.capacity);
    }

    latencies.sort_by(f64::total_cmp);
    let pick = |q: f64| {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() - 1) as f64 * q) as usize]
        }
    };
    let served_tokens = fully_served.iter().filter(|&&s| s).count();
    ServeSummary {
        requests: requests.len(),
        ticks: ticks.len(),
        total_tokens,
        served_tokens,
        degraded_tokens: total_tokens - served_tokens,
        dropped_slots,
        failed_rank_drops,
        degraded_ticks,
        rank_rows,
        rank_expert_s,
        tokens_per_s: if engine_free > 0.0 { total_tokens as f64 / engine_free } else { 0.0 },
        p50_s: pick(0.5),
        p99_s: pick(0.99),
        sim_elapsed_s: engine_free,
        busy_s,
        capacity_range: if cap_min == usize::MAX { (0, 0) } else { (cap_min, cap_max) },
        mean_batch_tokens: if ticks.is_empty() {
            0.0
        } else {
            total_tokens as f64 / ticks.len() as f64
        },
        y,
        fully_served,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::layer::{moe_forward, MoeWeights};
    use crate::serve::gen::{generate_requests, ArrivalMode, GenConfig};

    fn engine(recipe: Recipe, ranks: usize, cf: f64, policy: DropPolicy) -> ServeEngine {
        let mut rng = Rng::seed_from(11);
        let w = MoeWeights::random(32, 24, 4, &mut rng);
        ServeEngine::new(
            PreparedWeights::new(w, recipe),
            TokenEmbed::new(64, 32, 11),
            ServeConfig {
                ranks,
                top_k: 2,
                capacity_factor: cf,
                drop_policy: policy,
                threads: 1,
                chunks: 1,
                overlap: false,
            },
        )
    }

    #[test]
    fn empty_tick_is_defined() {
        let eng = engine(Recipe::Fp8Flow, 2, 1.0, DropPolicy::Capacity);
        let res = eng.forward_batch(&Mat::zeros(0, 32));
        assert_eq!(res.y.rows, 0);
        assert_eq!(res.dropped_slots, 0);
        assert!(res.fully_served.is_empty());
        assert_eq!(res.rank_rows, vec![0, 0]);
    }

    #[test]
    fn drop_accounting_reconciles_per_tick() {
        // cf = 0.25 → cap = ceil(t/8) < the pigeonhole max-load bound t/4,
        // so drops are guaranteed, not just likely under skew
        let eng = engine(Recipe::Fp8Flow, 2, 0.25, DropPolicy::Capacity);
        let reqs = generate_requests(&GenConfig::default(), 48);
        let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
        let x = eng.embed.embed(&ids);
        let res = eng.forward_batch(&x);
        let real: usize = res.rank_rows.iter().sum();
        assert_eq!(real + res.dropped_slots, x.rows * eng.cfg.top_k);
        assert!(res.dropped_slots > 0, "cf=0.25 must drop by pigeonhole");
    }

    #[test]
    fn nodrop_policy_serves_everything_bit_identically() {
        let eng = engine(Recipe::Fp8Flow, 2, 0.25, DropPolicy::None);
        let reqs = generate_requests(&GenConfig::default(), 32);
        let slo = SloPolicy { max_wait_s: 0.01, max_tokens: 64 };
        let s = serve_trace(&eng, &reqs, &slo);
        assert_eq!(s.dropped_slots, 0);
        assert_eq!(s.served_tokens, s.total_tokens);
        // one-shot over the same token stream, capacity = t (no drops)
        let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
        let x = eng.embed.embed(&ids);
        let one = moe_forward(&x, &eng.weights, eng.cfg.top_k, x.rows);
        for (a, b) in s.y.data.iter().zip(&one.y.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn crashed_rank_ledger_balances_under_both_policies() {
        use crate::cluster::fault::{Fault, FaultKind, ANY_DST};
        for policy in [FailoverPolicy::Reroute, FailoverPolicy::Drop] {
            let plan = FaultPlan::new(vec![Fault {
                tick: 1,
                src: 1,
                dst: ANY_DST,
                kind: FaultKind::CrashRank,
                attempts: 1,
            }]);
            let eng = engine(Recipe::Fp8Flow, 2, 1.0, DropPolicy::Capacity)
                .with_faults(plan, policy);
            let reqs = generate_requests(&GenConfig::default(), 40);
            let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
            let x = eng.embed.embed(&ids);
            for tick in 0..3usize {
                let res = eng.forward_batch_at(tick, &x);
                let real: usize = res.rank_rows.iter().sum();
                assert_eq!(
                    real + res.dropped_slots + res.failed_rank_drops,
                    x.rows * eng.cfg.top_k,
                    "{policy:?} tick {tick}: the extended ledger must balance"
                );
                if tick == 0 {
                    assert!(!res.degraded);
                    assert_eq!(res.failed_rank_drops, 0);
                } else {
                    assert!(res.degraded);
                    assert_eq!(res.rank_rows[1], 0, "a dead rank serves nothing");
                    if policy == FailoverPolicy::Drop || tick == 1 {
                        // crash-tick in-flight loss, or standing Drop loss
                        assert!(res.failed_rank_drops > 0);
                    } else {
                        assert_eq!(res.failed_rank_drops, 0, "survivors serve everything");
                    }
                }
            }
        }
    }

    #[test]
    fn reroute_steady_state_is_bit_identical_to_healthy() {
        use crate::cluster::fault::{Fault, FaultKind, ANY_DST};
        let reqs = generate_requests(&GenConfig::default(), 24);
        let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
        let healthy = engine(Recipe::Fp8Flow, 2, 0.25, DropPolicy::None);
        let x = healthy.embed.embed(&ids);
        let y0 = healthy.forward_batch_at(5, &x);
        let plan = FaultPlan::new(vec![Fault {
            tick: 1,
            src: 1,
            dst: ANY_DST,
            kind: FaultKind::CrashRank,
            attempts: 1,
        }]);
        let eng = engine(Recipe::Fp8Flow, 2, 0.25, DropPolicy::None)
            .with_faults(plan, FailoverPolicy::Reroute);
        let _ = eng.forward_batch_at(1, &x); // consume the crash (in-flight loss)
        let y1 = eng.forward_batch_at(5, &x); // steady-state degraded tick
        assert!(y1.degraded);
        assert_eq!(y1.rank_rows[1], 0);
        assert_eq!(y1.failed_rank_drops, 0);
        assert!(y1.fully_served.iter().all(|&s| s));
        for (a, b) in y0.y.data.iter().zip(&y1.y.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "reroute must not perturb numerics");
        }
    }

    #[test]
    fn wire_faults_recover_without_touching_outputs() {
        use crate::cluster::fault::{Fault, FaultKind, ANY_DST};
        let reqs = generate_requests(&GenConfig::default(), 16);
        let ids: Vec<i32> = reqs.iter().flat_map(|r| r.tokens.iter().copied()).collect();
        let clean = engine(Recipe::Fp8Flow, 2, 1.0, DropPolicy::Capacity);
        let x = clean.embed.embed(&ids);
        let y0 = clean.forward_batch_at(3, &x);
        let plan = FaultPlan::new(vec![
            Fault {
                tick: 3,
                src: 0,
                dst: ANY_DST,
                kind: FaultKind::FlipSidecarBit { offset: 2, bit: 0 },
                attempts: 1,
            },
            Fault { tick: 3, src: 1, dst: 0, kind: FaultKind::DropMessage, attempts: 1 },
        ]);
        let eng = engine(Recipe::Fp8Flow, 2, 1.0, DropPolicy::Capacity)
            .with_faults(plan, FailoverPolicy::Reroute);
        let y1 = eng.forward_batch_at(3, &x);
        for (a, b) in y0.y.data.iter().zip(&y1.y.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "recovered wire must be pristine");
        }
        let st = eng.fault_stats();
        assert_eq!(st.checksum_fails, 1, "one detected sidecar flip");
        assert_eq!(st.retries, 2, "one flip retry + one drop retry");
        assert_eq!(st.failovers, 0);
        assert!(st.clock_ns > 0);
    }

    #[test]
    fn latencies_and_throughput_are_populated() {
        for mode in [ArrivalMode::Poisson, ArrivalMode::Bursty] {
            let eng = engine(Recipe::Bf16, 1, 1.0, DropPolicy::Capacity);
            let reqs = generate_requests(&GenConfig { mode, ..GenConfig::default() }, 40);
            let slo = SloPolicy { max_wait_s: 0.005, max_tokens: 96 };
            let s = serve_trace(&eng, &reqs, &slo);
            assert_eq!(s.requests, 40);
            assert!(s.ticks >= 1);
            assert!(s.tokens_per_s > 0.0);
            assert!(s.p50_s > 0.0 && s.p99_s >= s.p50_s);
        }
    }
}
