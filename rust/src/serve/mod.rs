//! Heavy-traffic serving path (the ROADMAP's "millions of users"
//! workload): seeded request generation → SLO micro-batching → the
//! EP-sharded casting-free forward, with capacity-factor and token-drop
//! policies as first-class knobs.
//!
//! Three layers, each pure/deterministic where it can be:
//!
//! * [`gen`] — seeded Poisson/bursty arrivals, Zipf-skewed prompt
//!   lengths, prompt content from the [`crate::train::Corpus`] Markov
//!   stream (skewed token frequencies ⇒ skewed expert load);
//! * [`batch`] — the continuous micro-batcher: a pure function of the
//!   trace and the SLO (max-wait + max-tokens), so batch composition is
//!   reproducible across machines and worker budgets;
//! * [`engine`] — the EP-sharded serving loop over the
//!   [`crate::moe::layer`] stage APIs (optionally the overlapped EP
//!   pipeline), with exact per-(token, slot) drop accounting and the
//!   bit-identity contract vs one-shot `moe_forward`.
//!
//! Driven by the `serve` CLI subcommand; protocol and report schema in
//! `rust/EXPERIMENTS.md` §Serving.

pub mod batch;
pub mod engine;
pub mod gen;

pub use batch::{effective_capacity, schedule, DropPolicy, SloPolicy, Tick};
pub use engine::{
    serve_trace, FailoverPolicy, ServeConfig, ServeEngine, ServeSummary, TickResult, TokenEmbed,
};
pub use gen::{generate_requests, ArrivalMode, GenConfig, Request};
