//! Seeded request generator for the serving workload: Poisson or bursty
//! arrival times on a **virtual timeline**, Zipf-skewed prompt lengths,
//! prompt content drawn from the existing [`Corpus`] Markov stream.
//!
//! Everything is a pure function of the seed — no wall clock, no thread
//! interaction — so a request trace is reproducible across machines and
//! worker budgets (`tests/prop_serve.rs` pins this). The Markov content
//! stream is deliberately non-uniform: with a fixed per-token-id
//! embedding the router's choice is a function of the id, so skewed id
//! frequencies become skewed expert load — the serving condition where
//! capacity-factor and token-drop policies start to matter.

use crate::train::data::Corpus;
use crate::util::rng::Rng;

/// Arrival-process shape of the generated trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Homogeneous Poisson process: exponential inter-arrivals at `rate`.
    Poisson,
    /// On/off modulated Poisson: the timeline alternates between burst
    /// windows (arrivals at `burst × rate`) and quiet windows (arrivals
    /// at `rate / burst`), each window `burst_period_s` long.
    Bursty,
}

impl ArrivalMode {
    /// Parse a mode name as the CLI spells it.
    pub fn parse(s: &str) -> Option<ArrivalMode> {
        match s {
            "poisson" => Some(ArrivalMode::Poisson),
            "bursty" => Some(ArrivalMode::Bursty),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            ArrivalMode::Poisson => "poisson",
            ArrivalMode::Bursty => "bursty",
        }
    }
}

/// Generator configuration. All fields are knobs of the seeded trace;
/// two configs that compare equal generate bitwise-identical traces.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Master seed (drives arrivals, lengths, and the corpus stream).
    pub seed: u64,
    /// Arrival-process shape.
    pub mode: ArrivalMode,
    /// Mean arrival rate in requests per virtual second.
    pub rate: f64,
    /// Burst intensity for [`ArrivalMode::Bursty`] (≥ 1; 1 = Poisson).
    pub burst: f64,
    /// Window length of each burst/quiet phase (virtual seconds).
    pub burst_period_s: f64,
    /// Zipf exponent for the prompt-length distribution (0 = uniform).
    pub zipf_s: f64,
    /// Shortest prompt length (tokens).
    pub min_len: usize,
    /// Longest prompt length (tokens).
    pub max_len: usize,
    /// Corpus vocabulary size.
    pub vocab: usize,
    /// Corpus noise percentage (see [`Corpus::new`]).
    pub noise_pct: usize,
}

impl Default for GenConfig {
    fn default() -> GenConfig {
        GenConfig {
            seed: 42,
            mode: ArrivalMode::Poisson,
            rate: 200.0,
            burst: 4.0,
            burst_period_s: 0.05,
            zipf_s: 1.1,
            min_len: 4,
            max_len: 64,
            vocab: 64,
            noise_pct: 10,
        }
    }
}

/// One generated request: an arrival instant on the virtual timeline plus
/// the prompt token ids.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Sequential request id (also the arrival order).
    pub id: usize,
    /// Arrival instant (virtual seconds from trace start).
    pub arrival_s: f64,
    /// Prompt token ids (length is the Zipf-skewed prompt length).
    pub tokens: Vec<i32>,
}

impl Request {
    /// Prompt length in tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True when the prompt is empty (never produced by the generator).
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Generate a seeded trace of `n` requests, sorted by arrival time (the
/// arrival process emits them in order by construction).
pub fn generate_requests(cfg: &GenConfig, n: usize) -> Vec<Request> {
    assert!(cfg.rate > 0.0, "arrival rate must be positive");
    assert!(cfg.burst >= 1.0, "burst intensity must be >= 1");
    assert!(
        1 <= cfg.min_len && cfg.min_len <= cfg.max_len,
        "need 1 <= min_len <= max_len"
    );
    let mut rng = Rng::seed_from(cfg.seed ^ 0x5E21E);
    let mut corpus = Corpus::new(cfg.vocab, cfg.seed, cfg.noise_pct);
    let zipf = ZipfLengths::new(cfg.min_len, cfg.max_len, cfg.zipf_s);

    let mut now = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        now += sample_interarrival(cfg, now, &mut rng);
        let len = zipf.sample(&mut rng);
        out.push(Request { id, arrival_s: now, tokens: corpus.next_batch(1, len) });
    }
    out
}

/// Draw the next inter-arrival gap at virtual time `now`.
fn sample_interarrival(cfg: &GenConfig, now: f64, rng: &mut Rng) -> f64 {
    let rate = match cfg.mode {
        ArrivalMode::Poisson => cfg.rate,
        ArrivalMode::Bursty => {
            let phase = (now / cfg.burst_period_s) as u64;
            if phase % 2 == 0 {
                cfg.rate * cfg.burst
            } else {
                cfg.rate / cfg.burst
            }
        }
    };
    // exponential via inverse CDF; uniform() < 1 so the log argument > 0
    -(1.0 - rng.uniform() as f64).ln() / rate
}

/// Zipf-skewed length sampler over `[min_len, max_len]`: rank 1 (the
/// shortest prompt) is most probable, `P(rank r) ∝ r^{-s}`. `s = 0`
/// degenerates to uniform. Sampling is inverse-CDF over the precomputed
/// cumulative weights, one `uniform()` draw per request.
struct ZipfLengths {
    min_len: usize,
    cdf: Vec<f64>,
}

impl ZipfLengths {
    fn new(min_len: usize, max_len: usize, s: f64) -> ZipfLengths {
        let n = max_len - min_len + 1;
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 1..=n {
            acc += (r as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfLengths { min_len, cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform() as f64;
        let idx = self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1);
        self.min_len + idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_seeded_and_sorted() {
        let cfg = GenConfig::default();
        let a = generate_requests(&cfg, 64);
        let b = generate_requests(&cfg, 64);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(a.iter().all(|r| (cfg.min_len..=cfg.max_len).contains(&r.len())));
        assert!(a.iter().all(|r| r.tokens.iter().all(|&t| (t as usize) < cfg.vocab)));
    }

    #[test]
    fn zipf_skews_short() {
        // s > 0 must make the shortest quartile more common than the longest
        let cfg = GenConfig { zipf_s: 1.5, min_len: 4, max_len: 64, ..GenConfig::default() };
        let reqs = generate_requests(&cfg, 512);
        let q = (cfg.max_len - cfg.min_len) / 4;
        let short = reqs.iter().filter(|r| r.len() <= cfg.min_len + q).count();
        let long = reqs.iter().filter(|r| r.len() >= cfg.max_len - q).count();
        assert!(short > 4 * long.max(1), "short {short} vs long {long}");
    }

    #[test]
    fn bursty_clusters_more_than_poisson() {
        // coefficient of variation of inter-arrivals: bursty > poisson (≈1)
        let cv = |mode: ArrivalMode| {
            let cfg = GenConfig { mode, burst: 8.0, ..GenConfig::default() };
            let reqs = generate_requests(&cfg, 2048);
            let gaps: Vec<f64> =
                reqs.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / mean
        };
        assert!(cv(ArrivalMode::Bursty) > cv(ArrivalMode::Poisson) * 1.2);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate_requests(&GenConfig::default(), 32);
        let b = generate_requests(&GenConfig { seed: 43, ..GenConfig::default() }, 32);
        assert_ne!(a, b);
    }
}
