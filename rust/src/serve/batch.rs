//! Continuous micro-batcher: coalesce pending requests into flush ticks
//! under a latency SLO (max-wait + max-tokens), FIFO and deterministic.
//!
//! [`schedule`] is a **pure function of the arrival trace and the SLO** —
//! flush decisions never look at measured service time, so the batch
//! composition is reproducible across machines and worker budgets (the
//! engine layers queueing delay on top when it falls behind;
//! `serve::engine`). Capacity-factor and token-drop policy are the other
//! two serving knobs; they live here as [`DropPolicy`] +
//! [`effective_capacity`] so the engine and the tests share one
//! definition.

use super::gen::Request;

/// The latency SLO the batcher flushes under.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Longest a pending request may wait in the queue before its batch
    /// is cut (virtual seconds).
    pub max_wait_s: f64,
    /// Token threshold: the batch is cut as soon as pending tokens reach
    /// this count (the final request may overshoot by its own length).
    pub max_tokens: usize,
}

/// What happens to tokens routed past an expert's capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropPolicy {
    /// Standard MoE capacity semantics: per-slot tokens beyond
    /// `capacity_factor`-scaled capacity are dropped (and accounted).
    Capacity,
    /// No drops: capacity is raised to the batch token count, the upper
    /// bound on any expert's per-slot load.
    None,
}

impl DropPolicy {
    /// Parse a policy name as the CLI spells it.
    pub fn parse(s: &str) -> Option<DropPolicy> {
        match s {
            "capacity" | "drop" => Some(DropPolicy::Capacity),
            "none" | "nodrop" => Some(DropPolicy::None),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            DropPolicy::Capacity => "capacity",
            DropPolicy::None => "none",
        }
    }
}

/// Per-expert per-slot row budget for a flush of `batch_tokens` tokens:
/// `ceil(cf · batch_tokens · top_k / E)` under [`DropPolicy::Capacity`]
/// (the trainer's default capacity is exactly `cf = 1` of this), or the
/// drop-free upper bound `batch_tokens` under [`DropPolicy::None`].
/// Always ≥ 1 so the stage APIs' non-empty invariants hold.
pub fn effective_capacity(
    policy: DropPolicy,
    capacity_factor: f64,
    batch_tokens: usize,
    top_k: usize,
    n_experts: usize,
) -> usize {
    match policy {
        DropPolicy::None => batch_tokens.max(1),
        DropPolicy::Capacity => {
            let raw = capacity_factor * (batch_tokens * top_k) as f64 / n_experts as f64;
            (raw.ceil() as usize).max(1)
        }
    }
}

/// One flush: the requests coalesced into a single `RankLocalBatch`-bound
/// micro-batch, cut at `flush_s` on the virtual timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct Tick {
    /// Instant the batch was cut (virtual seconds).
    pub flush_s: f64,
    /// Indices into the request trace, in arrival (FIFO) order.
    pub requests: Vec<usize>,
    /// Total prompt tokens across `requests`.
    pub tokens: usize,
}

/// Cut the arrival trace into flush ticks under `slo`. Requests must be
/// sorted by arrival (the generator emits them sorted). Guarantees:
///
/// * every request lands in exactly one tick, in FIFO order;
/// * no request waits in the queue longer than `max_wait_s`
///   (`flush_s − arrival_s ≤ max_wait_s`);
/// * a tick is cut early the moment pending tokens reach `max_tokens`;
/// * no tick is empty.
pub fn schedule(requests: &[Request], slo: &SloPolicy) -> Vec<Tick> {
    assert!(slo.max_wait_s >= 0.0, "max_wait_s must be non-negative");
    assert!(slo.max_tokens >= 1, "max_tokens must be at least 1");
    debug_assert!(requests.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));

    let mut ticks = Vec::new();
    let mut pending: Vec<usize> = Vec::new();
    let mut pend_tokens = 0usize;
    let mut flush = |pending: &mut Vec<usize>, pend_tokens: &mut usize, at: f64| {
        ticks.push(Tick { flush_s: at, requests: std::mem::take(pending), tokens: *pend_tokens });
        *pend_tokens = 0;
    };

    for (i, r) in requests.iter().enumerate() {
        if let Some(&oldest) = pending.first() {
            let deadline = requests[oldest].arrival_s + slo.max_wait_s;
            if deadline <= r.arrival_s {
                flush(&mut pending, &mut pend_tokens, deadline);
            }
        }
        pending.push(i);
        pend_tokens += r.len();
        if pend_tokens >= slo.max_tokens {
            flush(&mut pending, &mut pend_tokens, r.arrival_s);
        }
    }
    if let Some(&oldest) = pending.first() {
        let deadline = requests[oldest].arrival_s + slo.max_wait_s;
        flush(&mut pending, &mut pend_tokens, deadline);
    }
    ticks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::gen::{generate_requests, ArrivalMode, GenConfig};

    fn trace(mode: ArrivalMode, n: usize) -> Vec<Request> {
        generate_requests(&GenConfig { mode, ..GenConfig::default() }, n)
    }

    #[test]
    fn ticks_partition_the_trace_in_order() {
        for mode in [ArrivalMode::Poisson, ArrivalMode::Bursty] {
            let reqs = trace(mode, 200);
            let slo = SloPolicy { max_wait_s: 0.02, max_tokens: 128 };
            let ticks = schedule(&reqs, &slo);
            let flat: Vec<usize> = ticks.iter().flat_map(|t| t.requests.clone()).collect();
            assert_eq!(flat, (0..reqs.len()).collect::<Vec<_>>());
            for t in &ticks {
                assert!(!t.requests.is_empty());
                assert_eq!(t.tokens, t.requests.iter().map(|&i| reqs[i].len()).sum::<usize>());
            }
        }
    }

    #[test]
    fn no_request_waits_past_the_slo() {
        let reqs = trace(ArrivalMode::Bursty, 300);
        let slo = SloPolicy { max_wait_s: 0.015, max_tokens: 256 };
        for t in schedule(&reqs, &slo) {
            for &i in &t.requests {
                let wait = t.flush_s - reqs[i].arrival_s;
                assert!(
                    (0.0..=slo.max_wait_s + 1e-12).contains(&wait),
                    "req {i} waited {wait}"
                );
            }
        }
    }

    #[test]
    fn token_threshold_cuts_early() {
        let reqs = trace(ArrivalMode::Poisson, 300);
        let slo = SloPolicy { max_wait_s: 10.0, max_tokens: 96 };
        let ticks = schedule(&reqs, &slo);
        // with a huge max-wait every tick but the trailing one is cut by
        // the token threshold, overshooting by less than one request
        let max_len = reqs.iter().map(Request::len).max().unwrap();
        for t in &ticks[..ticks.len() - 1] {
            assert!(t.tokens >= slo.max_tokens);
            assert!(t.tokens < slo.max_tokens + max_len);
        }
    }

    #[test]
    fn effective_capacity_matches_trainer_default_at_cf1() {
        // trainer default: (tokens * top_k).div_ceil(experts)
        for (t, k, e) in [(512usize, 2usize, 8usize), (96, 3, 4), (7, 1, 8)] {
            assert_eq!(
                effective_capacity(DropPolicy::Capacity, 1.0, t, k, e),
                (t * k).div_ceil(e)
            );
        }
        assert_eq!(effective_capacity(DropPolicy::None, 0.25, 40, 2, 8), 40);
        // cf scales the budget down
        assert!(
            effective_capacity(DropPolicy::Capacity, 0.5, 512, 2, 8)
                < effective_capacity(DropPolicy::Capacity, 1.0, 512, 2, 8)
        );
    }
}
